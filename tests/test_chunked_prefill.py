"""Chunked prefill (Sarathi-style mixed prefill+decode steps): greedy
outputs must be bit-identical to serial admission-time prefill across
chunk sizes (including chunk < block_size and chunk > prompt), survive
preemption of half-prefilled requests, compose with the prefix cache,
and fix the admission-path bugs that rode along (max_new_tokens=1
double-emit, silent overlong-prompt admission, the dead TTFT re-stamp)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           StepFunctions, long_short_workload,
                           shared_prefix_workload, sharegpt_like)
from repro.serving.workload import Request


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    # one shared compile cache for every engine in this module (block
    # size must match the engines below)
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _engine(model, params, steps, **kw):
    ecfg = EngineConfig(**{**dict(max_batch=4, block_size=8,
                                  kv_pool_tokens=4096, max_model_len=256,
                                  prefill_bucket=16), **kw})
    return ContinuousBatchingEngine(model, params, ecfg, steps=steps)


def _mixed_reqs(cfg, seed=0):
    """Prompts straddling every chunk-size regime: shorter than a block,
    shorter than a chunk, several chunks long, non-block-aligned."""
    rng = np.random.default_rng(seed)
    lens = [5, 12, 40, 70, 23]
    return [Request(req_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=n).astype(np.int32),
                    max_new_tokens=6) for i, n in enumerate(lens)]


# ------------------------------------------------------- bit identity --
@pytest.mark.parametrize("chunk", [6, 8, 24, 1024])
def test_chunked_outputs_bit_identical(setup, chunk):
    """chunk=6 < block_size=8 (mid-block chunk boundaries), chunk=24
    (several chunks per prompt), chunk=1024 > every prompt (whole-prompt
    chunks): all must reproduce serial prefill token-for-token."""
    cfg, params, model, steps = setup
    outs = {}
    for c in (None, chunk):
        eng = _engine(model, params, steps, prefill_chunk_tokens=c)
        assert eng.chunking == (c is not None)
        reqs = _mixed_reqs(cfg)
        eng.run(reqs)
        assert all(r.t_done is not None for r in reqs)
        outs[c] = [r.output_tokens for r in reqs]
    assert outs[chunk] == outs[None]


def test_chunked_mixed_steps_interleave(setup):
    """While a long prompt streams in, short requests keep decoding: the
    engine must record steps whose mixed batch carries both prefill and
    decode tokens, and the stall series must exist in the metrics."""
    cfg, params, model, steps = setup
    eng = _engine(model, params, steps, prefill_chunk_tokens=16,
                  max_model_len=512, kv_pool_tokens=8192)
    reqs = long_short_workload(4, 2, cfg.vocab_size, short_len=10,
                               long_len=120, short_new=20, long_new=4,
                               every=2, seed=1)
    m = eng.run(reqs)
    assert all(r.t_done is not None for r in reqs)
    mixed = [i for i, (p, d) in enumerate(zip(eng.prefill_token_samples,
                                              eng.decode_token_samples))
             if p > 0 and d > 0]
    assert mixed, "no step carried prefill chunks and decodes together"
    # chunk budget respected per step
    assert max(eng.prefill_token_samples) <= 16
    assert m.stall_series and m.stall_s_mean > 0.0
    assert m.prefill_tokens_per_step > 0.0
    assert m.decode_tokens_per_step > 0.0


# -------------------------------------------------------- preemption --
def test_chunked_preempts_half_prefilled(setup):
    """Tiny pool: the long prompt's chunks exhaust free blocks while
    decodes need append room — the scheduler must preempt the
    half-prefilled request (releasing its partial KV), re-admit it
    later, and still produce serial-identical outputs."""
    cfg, params, model, steps = setup
    kw = dict(kv_pool_tokens=256, max_batch=4, max_model_len=256)
    outs = {}
    for c in (None, 16):
        rng = np.random.default_rng(1)
        mk = lambda i, n, new: Request(
            req_id=i, prompt=rng.integers(0, cfg.vocab_size,
                                          size=n).astype(np.int32),
            max_new_tokens=new)
        reqs = [mk(0, 40, 40), mk(1, 40, 40), mk(2, 150, 4)]
        eng = _engine(model, params, steps, prefill_chunk_tokens=c, **kw)
        eng.run(reqs)
        assert all(r.t_done is not None for r in reqs)
        outs[c] = ([r.output_tokens for r in reqs], eng.preemptions)
    assert outs[16][1] >= 1, "pool pressure never preempted the prefill"
    assert outs[16][0] == outs[None][0]
    # preempted request left no residue
    eng = _engine(model, params, steps, prefill_chunk_tokens=16, **kw)
    assert not eng.prefilling and not eng._prefilled


def test_oversized_request_fails_loudly(setup):
    """A request that can never fit the pool must raise, not spin the
    run loop forever (serial) or stream chunks into a wall (chunked)."""
    cfg, params, model, steps = setup
    rng = np.random.default_rng(0)
    for c in (None, 32):
        eng = _engine(model, params, steps, kv_pool_tokens=128,
                      max_model_len=128, prefill_chunk_tokens=c)
        req = Request(req_id=0,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          size=120).astype(np.int32),
                      max_new_tokens=4)
        with pytest.raises(RuntimeError, match="KV pool exhausted"):
            eng.run([req])


# ------------------------------------------------------ prefix cache --
def test_chunked_with_prefix_cache(setup):
    """Prefix-cache hits compose with chunking: the cached prefix is
    spliced (skipping its prefill work) and the suffix streams in
    chunks, with outputs identical to the serial cache-off engine."""
    cfg, params, model, steps = setup
    outs, stats = {}, {}
    for tag, kw in (("serial", {}),
                    ("chunked+pfx", dict(prefill_chunk_tokens=12,
                                         prefix_cache=True))):
        eng = _engine(model, params, steps, kv_pool_tokens=8192, **kw)
        reqs = shared_prefix_workload(2, 4, cfg.vocab_size, prefix_len=32,
                                      suffix_len=20, max_new_tokens=5,
                                      seed=0)
        eng.run(reqs)
        assert all(r.t_done is not None for r in reqs)
        outs[tag] = [r.output_tokens for r in reqs]
        stats[tag] = eng
    assert outs["chunked+pfx"] == outs["serial"]
    eng = stats["chunked+pfx"]
    assert eng.prefix is not None and eng.prefix.stats.hit_tokens > 0
    assert (eng.prefill_tokens_computed
            < stats["serial"].prefill_tokens_computed)


def test_chunking_downgrades_unsupported_config(rules):
    """SSM state is not per-token addressable: chunking silently falls
    back to serial prefill with the reason recorded."""
    cfg = reduced(get_config("mamba2-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(
        Model(cfg, rules), params,
        EngineConfig(max_batch=2, block_size=8, kv_pool_tokens=1024,
                     max_model_len=128, prefill_bucket=16,
                     prefill_chunk_tokens=16))
    assert not eng.chunking
    assert eng.chunking_disabled_reason
    reqs = sharegpt_like(2, cfg.vocab_size, seed=0, mean_in=10, mean_out=4,
                         max_len=48, sigma=0.3)
    eng.run(reqs)
    assert all(r.t_done is not None for r in reqs)


# -------------------------------------------------- satellite bugfixes --
@pytest.mark.parametrize("chunk", [None, 16])
def test_max_new_tokens_one_emits_one_token(setup, chunk):
    """Prefill emits the first output token; a max_new_tokens=1 request
    is complete right there and must never enter the decode batch (it
    used to emit 2 tokens)."""
    cfg, params, model, steps = setup
    eng = _engine(model, params, steps, prefill_chunk_tokens=chunk)
    rng = np.random.default_rng(3)
    reqs = [Request(req_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=12 + i).astype(np.int32),
                    max_new_tokens=1) for i in range(2)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.output_tokens) == 1
        assert r.generated == 1
        assert r.t_done is not None and r.t_first_token is not None
        assert r.t_done >= r.t_first_token >= r.arrival_s
    # nothing leaked into the decode phase
    assert not eng.running and not eng._tokens and not eng._pos


def test_overlong_prompt_rejected(setup):
    cfg, params, model, steps = setup
    eng = _engine(model, params, steps, max_model_len=64)
    req = Request(req_id=0, prompt=np.zeros(64, np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="max_model_len"):
        eng.add_request(req)
    # boundary: prompt_len + 1 == max_model_len is admissible and
    # completes with exactly the one prefill token
    ok = Request(req_id=1, prompt=np.zeros(63, np.int32), max_new_tokens=5)
    eng.run([ok])
    assert ok.t_done is not None and len(ok.output_tokens) == 1


def test_sharegpt_fixed_clamps_to_max_len():
    reqs = sharegpt_like(3, 100, fixed=True, mean_in=5000, mean_out=9000,
                         max_len=256)
    assert all(r.prompt_len == 128 for r in reqs)
    assert all(r.max_new_tokens == 128 for r in reqs)


def test_ttft_stamped_at_prefill_and_after_preemption(setup):
    """TTFT regression for the removed decode-path re-stamp: every
    completed request's TTFT is stamped when its prefill produced the
    first token — including requests that were preempted (TTFT reset to
    None) and re-admitted — and is never before arrival or after
    t_done."""
    cfg, params, model, steps = setup
    rng = np.random.default_rng(5)
    mk = lambda i, n, new: Request(
        req_id=i, prompt=rng.integers(0, cfg.vocab_size,
                                      size=n).astype(np.int32),
        max_new_tokens=new)
    # tiny pool + growing decodes forces preemption of the youngest:
    # two requests decoding to 120 tokens need 30 blocks, the pool has 24
    eng = _engine(model, params, steps, kv_pool_tokens=192, max_batch=3,
                  max_model_len=128)
    reqs = [mk(0, 30, 90), mk(1, 30, 90), mk(2, 30, 8)]
    for r in reqs:
        eng.add_request(r)
    now, preempted_seen = 0.0, False
    for step in range(400):
        if not eng.busy:
            break
        eng.step(float(step))
        for r in reqs:
            if r in eng.waiting and r.generated == 0 and step > 0:
                # a preempted request has its TTFT reset
                assert r.t_first_token is None
                preempted_seen = preempted_seen or eng.preemptions > 0
    assert eng.preemptions >= 1 and preempted_seen
    for r in reqs:
        assert r.t_done is not None
        assert r.t_first_token is not None
        assert r.arrival_s <= r.t_first_token <= r.t_done


def test_engine_config_validates_chunk():
    with pytest.raises(ValueError, match="prefill_chunk_tokens"):
        EngineConfig(prefill_chunk_tokens=0)


# ------------------------------------------------------------ metrics --
def test_serial_stall_visible_in_metrics(setup):
    """The HOL stall must be measurable: a serial engine serving a long
    prompt amid running decodes records the prefill inside the step
    timer (stall series) instead of hiding it before the timer starts."""
    cfg, params, model, steps = setup
    eng = _engine(model, params, steps, max_model_len=512,
                  kv_pool_tokens=8192)
    reqs = long_short_workload(3, 1, cfg.vocab_size, short_len=8,
                               long_len=200, short_new=12, long_new=2,
                               every=3, seed=2)
    m = eng.run(reqs)
    assert m.stall_series and max(m.stall_series) > 0.0
    # the long prefill step dominates the stall series
    assert m.stall.p99 >= np.percentile(m.stall_series, 50)
    assert m.prefill_tokens_per_step > 0.0


# ---------------------------------------------------------- BCA hook --
def test_bca_chunk_budget():
    from repro.core import (H100_PAPER, BatchingConfigurationAdvisor,
                            chunk_budget_for, decode_curves)
    cfg = get_config("opt-1.3b")
    curves = decode_curves(cfg, H100_PAPER, ctx=331, max_batch=64)
    slo = float(curves.itl_s.max()) * 2
    # more SLO headroom -> bigger chunk budget; floored at the quantum
    c_tight = chunk_budget_for(curves, 64, float(curves.itl_s.max()),
                               1e-3, quantum=16)
    c_loose = chunk_budget_for(curves, 64, slo, 1e-6, quantum=16)
    assert c_tight == 16            # no headroom -> floor
    assert c_loose > c_tight
    assert c_loose % 16 == 0
    with pytest.raises(ValueError, match="prefill_token_s"):
        chunk_budget_for(curves, 64, slo, 0.0)
    # advisor integration: chunk_tokens appears (and in the summary)
    res = BatchingConfigurationAdvisor(curves, slo_s=slo,
                                       prefill_token_s=1e-6).solve()
    assert res.chunk_tokens and res.chunk_tokens % 16 == 0
    assert "chunk=" in res.summary()
    res0 = BatchingConfigurationAdvisor(curves, slo_s=slo).solve()
    assert res0.chunk_tokens is None
