"""Serving engine integration: continuous batching correctness — the
engine's greedy outputs must match a naive one-request-at-a-time
autoregressive loop through the raw model (paged cache + ragged batching
must be invisible to the math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, decode_step, init_params, prefill
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           sharegpt_like)


@pytest.fixture(scope="module")
def setup(request):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _naive_generate(cfg, rules, params, prompt, n_new):
    toks = jnp.asarray(prompt[None])
    lg, cache, pos = prefill(params, cfg, rules, {"tokens": toks},
                             cache_len=len(prompt) + n_new)
    out = [int(jnp.argmax(lg[0]))]
    for i in range(n_new - 1):
        t = jnp.asarray([out[-1]], jnp.int32)
        lg, cache = decode_step(params, cfg, rules, cache, t,
                                jnp.int32(len(prompt) + i))
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_engine_matches_naive_generation(setup, rules):
    cfg, params = setup
    model = Model(cfg, rules)
    ecfg = EngineConfig(max_batch=4, block_size=8, kv_pool_tokens=4096,
                        max_model_len=256, prefill_bucket=16)
    engine = ContinuousBatchingEngine(model, params, ecfg)
    reqs = sharegpt_like(5, cfg.vocab_size, seed=2, mean_in=12, mean_out=8,
                         max_len=64, sigma=0.4)
    engine.run(reqs)
    for r in reqs:
        assert r.t_done is not None
        naive = _naive_generate(cfg, rules, params, r.prompt,
                                len(r.output_tokens))
        assert r.output_tokens == naive, (r.req_id, r.output_tokens, naive)


def test_engine_respects_max_batch(setup, rules):
    cfg, params = setup
    model = Model(cfg, rules)
    ecfg = EngineConfig(max_batch=3, block_size=8, kv_pool_tokens=4096,
                        max_model_len=128, prefill_bucket=16)
    engine = ContinuousBatchingEngine(model, params, ecfg)
    reqs = sharegpt_like(7, cfg.vocab_size, seed=3, mean_in=10, mean_out=6,
                         max_len=48, sigma=0.3)
    m = engine.run(reqs)
    assert max(engine.batch_samples) <= 3
    assert all(r.t_done is not None for r in reqs)
    assert m.total_tokens > 0


def test_engine_kv_admission(setup, rules):
    """Tiny KV pool: engine must still finish everything (queueing, not
    crashing) and never exceed pool capacity."""
    cfg, params = setup
    model = Model(cfg, rules)
    ecfg = EngineConfig(max_batch=8, block_size=8, kv_pool_tokens=512,
                        max_model_len=96, prefill_bucket=16)
    engine = ContinuousBatchingEngine(model, params, ecfg)
    reqs = sharegpt_like(6, cfg.vocab_size, seed=4, mean_in=16, mean_out=8,
                         max_len=64, sigma=0.3)
    m = engine.run(reqs)
    assert all(r.t_done is not None for r in reqs)
    assert m.max_kv_fraction <= 1.0
