"""Observability layer: bounded series, Chrome-trace writer + validator,
engine/cluster hook wiring (phases, live roofline, census cache),
no-effect-on-outputs invariance, and the periodic metrics emitter."""
import json

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (BoundedSeries, ContinuousBatchingEngine,
                           EngineConfig, FaultInjector, MetricsEmitter,
                           Observability, ReplicatedCluster, StepFunctions,
                           Tracer, shared_prefix_workload, sharegpt_like,
                           validate_chrome_trace)


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


def _engine(setup, **kw):
    _, params, model, steps = setup
    return ContinuousBatchingEngine(model, params, _ecfg(**kw), steps=steps)


def _wl(cfg, n=4, seed=3, mean_out=8):
    return sharegpt_like(n, cfg.vocab_size, seed=seed, mean_in=12,
                         mean_out=mean_out, max_len=48, sigma=0.4)


def _outputs(reqs):
    return [list(r.output_tokens) for r in reqs]


# ---------------------------------------------------------- BoundedSeries --
def test_bounded_series_below_cap_keeps_everything():
    s = BoundedSeries(16)
    for i in range(16):
        s.append(i)
    assert list(s) == list(range(16))
    assert s.appended == 16 and s.stride == 1


def test_bounded_series_decimates_above_cap():
    s = BoundedSeries(8)
    n = 1000
    for i in range(n):
        s.append(i)
    assert len(s) <= 8
    assert s.appended == n
    assert s.stride > 1
    # uniform whole-run coverage, not a tail window: retained points are
    # stride-spaced from the beginning of the run
    assert s[0] == 0
    assert list(s) == list(range(0, n, s.stride))[:len(s)]


def test_bounded_series_validation_and_fresh():
    with pytest.raises(ValueError):
        BoundedSeries(0)
    s = BoundedSeries(4)
    for i in range(100):
        s.append(i)
    f = s.fresh()
    assert f.maxlen == 4 and len(f) == 0 and f.stride == 1


def test_engine_series_are_bounded(setup):
    cfg = setup[0]
    eng = _engine(setup, series_maxlen=4)
    m = eng.run(_wl(cfg, n=6, mean_out=12))
    assert isinstance(eng.itl_samples, BoundedSeries)
    assert len(eng.itl_samples) <= 4
    assert eng.itl_samples.appended > 4          # the run outgrew the cap
    assert m.itl_s > 0 and m.itl.p50 > 0         # metrics still computed
    with pytest.raises(ValueError, match="series_maxlen"):
        _ecfg(series_maxlen=1)


# ----------------------------------------------------------------- Tracer --
def test_tracer_chrome_trace_structure(tmp_path):
    tr = Tracer()
    tr.name_process(0, "replica0")
    tr.name_thread(0, 0, "engine steps")
    t = tr.now()
    tr.span("step 1", t, t + 1e-3, pid=0, cat="step")
    tr.instant("first_token", t + 5e-4, pid=0, tid=3)
    tr.counter("kv", t + 1e-3, {"used": 0.5}, pid=0)
    path = str(tmp_path / "trace.json")
    tr.export_chrome_trace(path)
    assert validate_chrome_trace(path) == []
    doc = json.load(open(path))
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "C", "M"} <= phs


def test_tracer_bounded_and_validator_catches_garbage():
    tr = Tracer(max_events=4)
    for i in range(10):
        tr.instant(f"e{i}", float(i))
    assert tr.n_events <= 4 and tr.dropped == 6
    bad = {"traceEvents": [{"ph": "X", "name": "no-dur", "ts": 1.0,
                            "pid": 0, "tid": 0}]}
    assert validate_chrome_trace(bad)            # missing dur reported


# -------------------------------------------------------- engine wiring --
def test_engine_obs_phases_roofline_census(setup, tmp_path):
    cfg = setup[0]
    obs = Observability()
    eng = _engine(setup)
    obs.attach(eng)
    eng.run(_wl(cfg, n=4, mean_out=8))

    ob = obs.observer(0)
    assert ob is not None and len(ob.phases) > 0
    p = ob.phases[-1]
    total = p.schedule_s + p.dispatch_s + p.device_s + p.host_s
    assert total == pytest.approx(p.total_s, rel=0.05, abs=1e-4)

    assert obs.census.compiles > 0 and not obs.census.errors
    dec = ob.roofline.variant_samples("decode")
    assert dec and all(s.flops > 0 and s.bytes > 0 for s in dec)
    s = ob.roofline.summary("decode")
    assert 0 < s["bw_util_mean"] and s["bound"] in ("memory", "compute")
    rep = ob.roofline.report("decode")
    assert rep is not None and rep.memory_s > 0

    path = str(tmp_path / "t.json")
    obs.export_chrome_trace(path)
    assert validate_chrome_trace(path) == []
    names = {e["name"] for e in json.load(open(path))["traceEvents"]}
    assert {"queued", "decode", "first_token", "schedule",
            "dispatch", "device", "host"} <= names


def test_obs_attached_outputs_bit_identical(setup):
    # same engine config, same workload, observer on vs off: identical
    cfg = setup[0]
    base = _wl(cfg, n=4, mean_out=8)
    again = _wl(cfg, n=4, mean_out=8)
    e1, e2 = _engine(setup), _engine(setup)
    Observability().attach(e2)
    e1.run(base)
    e2.run(again)
    assert _outputs(base) == _outputs(again)


def test_obs_covers_chunked_and_prefix_variants(setup):
    cfg = setup[0]
    obs = Observability()
    eng = _engine(setup, prefix_cache=True, prefill_chunk_tokens=16)
    obs.attach(eng)
    reqs = shared_prefix_workload(2, 2, cfg.vocab_size, prefix_len=32,
                                  suffix_len=8, max_new_tokens=6, seed=5)
    eng.run(reqs)
    variants = {v for (v, _, _) in obs.census._cache}
    assert "decode" in variants and "prefill" in variants
    # prefix hits and later chunks exercise the other two entry points
    assert variants & {"prefix_prefill", "chunk_prefill"}


# ------------------------------------------------------- cluster wiring --
def test_cluster_attach_and_fault_events(setup):
    cfg, params, model, _ = setup
    faults = FaultInjector.parse("replica=1,step=3")
    cluster = ReplicatedCluster.colocated(model, params, _ecfg(), 2,
                                          policy="round-robin", mode="sync",
                                          faults=faults)
    obs = Observability()
    obs.attach_cluster(cluster)
    assert cluster.obs is obs and set(obs.observers) == {0, 1}
    m = cluster.run(_wl(cfg, n=6, mean_out=8))
    assert m.faults == 1 and m.completed == 6
    names = {e["name"] for e in obs.trace.to_dict()["traceEvents"]}
    assert "quarantine" in names and "redrive" in names
    assert validate_chrome_trace(obs.trace.to_dict()) == []


# ----------------------------------------------------------- emitter ----
def test_metrics_emitter_tick_gating(setup, tmp_path):
    cfg = setup[0]
    eng = _engine(setup)
    m = eng.run(_wl(cfg, n=2, mean_out=4))
    path = str(tmp_path / "m.json")
    em = MetricsEmitter(path, interval_s=10.0)
    calls = []

    def provider():
        calls.append(1)
        return m

    assert em.tick(0.0, provider) is True        # first tick emits
    assert em.tick(5.0, provider) is False       # not due: provider unpaid
    assert em.tick(10.0, provider) is True
    assert len(calls) == 2 and em.emits == 2
    from repro.serving import metrics_from_json
    got = metrics_from_json(path)
    assert got.total_tokens == m.total_tokens
    em.close(m)
    assert em.emits == 3
    with pytest.raises(ValueError):
        MetricsEmitter(fmt="xml")
    with pytest.raises(ValueError):
        MetricsEmitter(interval_s=0.0)
