"""Speculative decoding subsystem: prompt-lookup drafter (periodic
tiling, adaptive K, cooldown, context rebuild), token-granular rollback
edge cases (block boundaries, COW-shared blocks, exact accounting,
mid-prefill refusal), engine bit-identity (greedy / sampled / overlap),
live spec counters through the metrics pipeline, and the BCA
speculation advisor."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import H100_PAPER, SpecPlan, speculation_advisor
from repro.kvcache.paged import BlockManager
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           SamplingParams, lint_prometheus,
                           metrics_from_json, metrics_to_json,
                           prometheus_text, repetitive_workload)
from repro.serving.spec import PromptLookupDrafter
from repro.serving.workload import Request


# ----------------------------------------------------------- drafter sim --
class _Req:
    """Minimal request shape the drafter reads (req_id / prompt /
    prompt_len / state.output_tokens)."""
    class _St:
        pass

    def __init__(self, rid, prompt, out):
        self.req_id = rid
        self.prompt = np.asarray(prompt, np.int64)
        self.prompt_len = len(prompt)
        self.state = self._St()
        self.state.output_tokens = list(out)


def test_lookup_tiles_short_period_out_to_k():
    """A period-2 stream's most recent n-gram match has only a 2-token
    observed continuation; the prediction must extend it periodically."""
    d = PromptLookupDrafter(max_k=8, start_k=8)
    r = _Req(0, [7, 9, 7, 9, 7, 9], [])
    got = d.propose(r, 8)
    assert got.tolist() == [7, 9, 7, 9, 7, 9, 7, 9]


def test_lookup_prefers_longest_ngram():
    """[..1,2,3..]: the 3-gram match must beat a shorter-gram match at a
    more recent position."""
    d = PromptLookupDrafter(max_ngram=3, max_k=4, start_k=4)
    #      0  1  2  3  4  5  6  7  8
    ctx = [1, 2, 3, 5, 6, 3, 1, 2, 3]
    got = d.propose(_Req(0, ctx, []), 4)
    # tail 3-gram [1,2,3] matches at i=0 -> continuation starts with 5
    assert got[0] == 5


def test_propose_empty_on_novel_text():
    d = PromptLookupDrafter()
    r = _Req(0, list(range(100, 140)), [])   # all-distinct tokens
    assert d.propose(r, 8).size == 0


def test_drafter_reads_generated_history():
    """Matches must come from prompt + outputs, not the prompt alone."""
    d = PromptLookupDrafter(min_ngram=1)
    r = _Req(0, [1, 2, 3, 4], [50, 60, 70, 50, 60])
    got = d.propose(r, 2)
    assert got.size > 0 and got[0] == 70     # [50,60] recurred in output


def test_adaptive_k_full_acceptance_grows():
    d = PromptLookupDrafter(start_k=2, max_k=8)
    d.observe(0, 2, 2)
    assert d._k[0] == 4
    d.observe(0, 4, 4)
    assert d._k[0] == 8
    d.observe(0, 8, 8)
    assert d._k[0] == 8                      # capped at max_k


def test_adaptive_k_partial_resets_to_accepted():
    d = PromptLookupDrafter(start_k=8, max_k=8)
    d.observe(0, 3, 8)
    assert d._k[0] == 3
    d.observe(0, 0, 3)                       # total reject halves
    assert d._k[0] == 1


def test_reject_streak_triggers_cooldown():
    d = PromptLookupDrafter(start_k=4, streak_limit=2, cooldown=3)
    r = _Req(0, [7, 9, 7, 9, 7, 9], [])
    d.observe(0, 0, 4)
    d.observe(0, 0, 2)                       # second total reject
    for _ in range(3):                       # cooldown: no proposals
        assert d.propose(r, 8).size == 0
    assert d.propose(r, 8).size > 0          # then drafting resumes


def test_context_rebuilds_after_requeue_shrink():
    """Preemption resets output history; the incremental context buffer
    must rebuild instead of serving stale tokens."""
    d = PromptLookupDrafter()
    r = _Req(0, [7, 9, 7, 9], [1, 2, 3, 4, 5])
    d.propose(r, 4)                          # buffer now prompt+5 outputs
    r.state.output_tokens = []               # requeue wiped the outputs
    got = d.propose(r, 4)
    assert got.tolist() == [7, 9, 7, 9]      # prompt-only period-2 tiling


def test_forget_drops_all_request_state():
    d = PromptLookupDrafter()
    r = _Req(5, [7, 9, 7, 9], [])
    d.propose(r, 4)
    d.observe(5, 0, 4)
    d.forget(5)
    for store in (d._k, d._streak, d._cool, d._ctx):
        assert 5 not in store


def test_drafter_validates_construction():
    with pytest.raises(ValueError, match="min_ngram"):
        PromptLookupDrafter(max_ngram=2, min_ngram=3)
    with pytest.raises(ValueError, match="max_k"):
        PromptLookupDrafter(max_k=0)


# ------------------------------------------------------ rollback edges --
def test_truncate_frees_exact_block_boundary():
    bm = BlockManager(8, 8)
    bm.allocate(0, 24)                       # 3 blocks
    assert bm.truncate(0, bm.blocks_needed(17)) == []   # 17 tokens: 3 blocks
    dropped = bm.truncate(0, bm.blocks_needed(16))      # 16 tokens: 2 blocks
    assert len(dropped) == 1
    assert bm.free_blocks == 8 - 2
    assert len(bm.tables[0]) == 2
    assert bm.free_blocks + len(bm.refs) == bm.num_blocks


def test_truncate_to_zero_and_validation():
    bm = BlockManager(8, 8)
    bm.allocate(0, 20)
    assert len(bm.truncate(0, 0)) == 3       # full rollback keeps the table
    assert bm.tables[0] == [] and bm.free_blocks == 8
    with pytest.raises(ValueError, match="keep_blocks"):
        bm.truncate(0, -1)
    assert bm.truncate(99, 0) == []          # unknown request: no-op


def test_truncate_cow_shared_block_survives():
    """Rolling one fork back must not reclaim a block the other fork
    (or the prefix index) still owns — refcounts, not table length,
    decide reclamation."""
    bm = BlockManager(8, 8)
    blocks = bm.allocate(0, 16)              # 2 blocks
    bm.share(1, blocks)                      # fork: refcount 2 on both
    dropped = bm.truncate(1, 1)
    assert dropped == [blocks[1]]
    assert bm.ref_count(blocks[1]) == 1      # req 0 still owns it
    assert bm.free_blocks == 8 - 2           # nothing physically freed
    bm.truncate(0, 1)                        # last owner drops it
    assert bm.free_blocks == 8 - 1
    assert bm.free_blocks + len(bm.refs) == bm.num_blocks


# ------------------------------------------------- engine integration --
@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    return cfg, params, model


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


def _wl(cfg, seed=3, n=4, max_new=16, sampling=None):
    return repetitive_workload(n, cfg.vocab_size, prompt_len=32,
                               max_new_tokens=max_new, repeat_rate=1.0,
                               phrase_len=8, pool_size=1, seed=seed,
                               sampling=sampling)


def _outputs(reqs):
    return [list(map(int, r.output_tokens)) for r in reqs]


def _pair(cfg, params, model, seed=3, sampling=None, **ecfg_kw):
    outs = {}
    for spec in (False, True):
        eng = ContinuousBatchingEngine(model, params,
                                       _ecfg(speculate=spec, **ecfg_kw))
        if spec:
            assert eng.speculator is not None, eng.spec_disabled_reason
        reqs = _wl(cfg, seed=seed, sampling=sampling)
        m = eng.run(reqs)
        outs[spec] = _outputs(reqs)
    return outs, m, eng


def test_greedy_bit_identity_and_exact_accounting(setup):
    cfg, params, model = setup
    outs, m, eng = _pair(cfg, params, model)
    assert outs[False] == outs[True]
    assert m.spec_steps > 0 and m.spec_accepted > 0
    assert m.spec_drafted == m.spec_accepted + m.spec_rejected
    # every block came home after the rollbacks
    from repro.serving.obs.auditor import audit_engine
    wb = audit_engine(eng)
    assert wb.used_bytes == 0 and wb.block_pad_bytes == 0
    assert wb.physical_bytes == wb.pool_bytes
    assert eng.pool.manager.free_blocks == eng.pool.manager.num_blocks


def test_sampled_identity_with_prefix_and_chunked_prefill(setup):
    """The hard composition: temperature/top-k/top-p sampling + prefix
    cache + chunked prefill, speculation on vs off."""
    cfg, params, model = setup
    sampling = SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                              seed=11, max_new_tokens=16)
    outs, m, _ = _pair(cfg, params, model, seed=5, sampling=sampling,
                       prefix_cache=True, prefill_chunk_tokens=16)
    assert outs[False] == outs[True]
    assert m.spec_steps > 0


def test_overlap_mode_identity(setup):
    cfg, params, model = setup
    outs, m, _ = _pair(cfg, params, model, seed=7, overlap=True)
    assert outs[False] == outs[True]
    assert m.spec_steps > 0


def test_rollback_refused_mid_prefill(setup):
    cfg, params, model = setup
    eng = ContinuousBatchingEngine(model, params, _ecfg())
    eng._prefilled[42] = 16                  # chunked prefill in flight
    with pytest.raises(RuntimeError, match="PREFILLING"):
        eng.rollback_kv(42, 8)


def test_spec_counters_roundtrip_and_prometheus(setup):
    cfg, params, model = setup
    eng = ContinuousBatchingEngine(model, params, _ecfg(speculate=True))
    m = eng.run(_wl(cfg, seed=3))
    assert m.spec_steps > 0 and m.spec_drafted > 0
    assert 0.0 < m.spec_acceptance_rate <= 1.0
    got = metrics_from_json(metrics_to_json(m))
    assert dataclasses.asdict(got) == dataclasses.asdict(m)
    text = prometheus_text(m)
    assert lint_prometheus(text) == []
    assert f"repro_spec_steps_total {m.spec_steps}" in text
    assert f"repro_spec_accepted_tokens_total {m.spec_accepted}" in text
    assert "repro_spec_acceptance_rate" in text


def test_spec_disabled_reason_on_unsupported_path(setup):
    """Gather-mode (non-paged) decode can't roll back token-granularly;
    the engine must fall back with a recorded reason, not crash."""
    cfg, params, model = setup
    eng = ContinuousBatchingEngine(
        model, params, _ecfg(speculate=True, decode_mode="gather"))
    assert eng.speculator is None
    assert eng.spec_disabled_reason


# ------------------------------------------------------------- advisor --
def test_advisor_validates_inputs():
    cfg = reduced(get_config("opt-1.3b"))
    with pytest.raises(ValueError, match="alpha"):
        speculation_advisor(cfg, H100_PAPER, batch=1, alpha=1.0)
    with pytest.raises(ValueError, match="batch"):
        speculation_advisor(cfg, H100_PAPER, batch=0)
    with pytest.raises(ValueError, match="max_k"):
        speculation_advisor(cfg, H100_PAPER, batch=1, max_k=-1)


def test_advisor_small_batch_speculates():
    cfg = reduced(get_config("opt-1.3b"))
    sp = speculation_advisor(cfg, H100_PAPER, batch=2, alpha=0.6, max_k=8)
    assert isinstance(sp, SpecPlan) and sp.enabled
    assert 1 <= sp.k <= 8
    assert sp.speedup_x > 1.0
    assert sp.expected_tokens == pytest.approx(
        (1 - 0.6 ** (sp.k + 1)) / (1 - 0.6))
    assert "speculate" in sp.summary()


def test_advisor_past_break_even_disables():
    cfg = reduced(get_config("opt-1.3b"))
    huge = int(speculation_advisor(cfg, H100_PAPER,
                                   batch=1).break_even_batch) * 4
    sp = speculation_advisor(cfg, H100_PAPER, batch=huge, alpha=0.6)
    assert not sp.enabled and sp.k == 0
    assert sp.speedup_x == pytest.approx(1.0)
    assert "off" in sp.summary()


def test_advisor_zero_alpha_never_pays():
    cfg = reduced(get_config("opt-1.3b"))
    sp = speculation_advisor(cfg, H100_PAPER, batch=2, alpha=0.0)
    assert sp.expected_tokens == 1.0 and not sp.enabled
