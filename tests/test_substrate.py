"""Substrate tests: optimizer, data pipeline, checkpointing, paged cache,
sharding rules, SSM numerics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models import ssm as ssm_mod
from repro.sharding import ShardingRules, rules_for
from repro.training import (AdamWConfig, adamw_init, adamw_update,
                            load_checkpoint, make_train_step,
                            save_checkpoint, synthetic_batches)


def test_adamw_reduces_loss(rules):
    cfg = reduced(get_config("llama-2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, rules, AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=40)))
    data = synthetic_batches(cfg, batch=4, seq=32, seed=1)
    losses = []
    for _ in range(10):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_adamw_grad_clip():
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 1e6)}
    opt = AdamWConfig(grad_clip=1.0, lr=1e-2, warmup_steps=1, total_steps=2)
    _, _, gnorm = adamw_update(opt, p, g, adamw_init(p))
    assert float(gnorm) > 1e6  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path, rules):
    cfg = reduced(get_config("olmoe-1b-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    opt = adamw_init(params)
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params, opt, step=42)
    p2, o2, s = load_checkpoint(path, params, opt)
    assert s == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharding_divisibility_fallback():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = rules_for(mesh)
    # 1-device mesh: everything falls back to size-1 axes w/o error
    spec = rules.spec(("batch", "kv_seq", "kv_heads", None), (8, 64, 2, 64))
    assert spec is not None


def test_sharding_no_duplicate_axes():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = rules_for(mesh)
    spec = rules.spec(("d_model", "d_ff"), (64, 64))
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


def test_ssm_chunked_matches_stepwise(rules):
    """SSD chunked scan == naive per-token recurrence."""
    cfg = reduced(get_config("mamba2-1.3b"))
    s = cfg.ssm
    key = jax.random.PRNGKey(0)
    B, S, H, P, G, N = 2, 37, 8, s.head_dim, s.ngroups, s.d_state
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y_chunk, h_chunk = ssm_mod.ssd_chunked(xs, dt, A, B_, C_, cfg, rules)
    # naive recurrence
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A)[:, :, None, None]
        upd = dt[:, t][:, :, None, None] * xs[:, t][..., None] * \
            Bh[:, t][:, :, None, :]
        h = h * decay + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t]))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               atol=2e-4, rtol=1e-3)


def test_paged_cache_gather_scatter_roundtrip(rules):
    from repro.kvcache.paged import PagedKVCache
    cfg = reduced(get_config("internlm2-1.8b"))
    pool = PagedKVCache(cfg, num_blocks=32, block_size=8, max_batch=4)
    pool.manager.allocate(0, 20)
    pool.manager.allocate(1, 12)
    # write a recognizable prefill for request 0
    cache = M.init_cache(cfg, 1, 24)
    cache = jax.tree.map(lambda x: jnp.full_like(x, 3.0), cache)
    pool.write_prefill(0, cache)
    view = pool.gather([0, 1], pad_blocks=3)
    for leaf in jax.tree.leaves(view):
        if leaf.ndim == 5:            # [L, B, S, K, hd] paged kv leaf
            arr = np.asarray(leaf)
            assert np.allclose(arr[:, 0, :20], 3.0)   # request 0 rows
    # scatter one new token for request 0 at position 20
    pool.manager.append_token(0, 21)
    view2 = pool.gather([0], pad_blocks=3)
    marked = jax.tree.map(
        lambda x: x.at[..., 0, 20, :, :].set(7.0) if x.ndim == 5 else x,
        view2)
    pool.scatter_new_token([0], [20], marked)
    view3 = pool.gather([0], pad_blocks=3)
    for leaf in jax.tree.leaves(view3):
        if leaf.ndim == 5:
            assert np.allclose(np.asarray(leaf)[:, 0, 20], 7.0)


def test_workload_statistics():
    from repro.serving.workload import sharegpt_like
    reqs = sharegpt_like(500, 1000, seed=0)
    lin = np.mean([r.prompt_len for r in reqs])
    lout = np.mean([r.max_new_tokens for r in reqs])
    # lognormal around the ShareGPT means
    assert 100 < lin < 320
    assert 200 < lout < 650
