"""Decode-vs-forward consistency: teacher-forced decode through the KV
cache must reproduce the full-sequence forward logits at float tolerance —
across every architecture family, including sliding-window ring caches and
SSM state handoff."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M

CASES = [
    ("phi3-mini-3.8b", None),
    ("qwen2.5-3b", None),
    ("mamba2-1.3b", None),
    ("olmoe-1b-7b", None),
    ("zamba2-7b", None),
    ("llama-3.2-vision-90b", None),
    ("opt-1.3b", None),
    ("deepseek-coder-33b", None),
    ("phi3-mini-3.8b", 8),          # sliding-window ring cache
    ("internlm2-1.8b", 16),
]


@pytest.mark.parametrize("name,window", CASES)
def test_decode_matches_forward(name, window, rules):
    cfg = reduced(get_config(name))
    if cfg.arch_type == "hybrid":
        cfg = dataclasses.replace(cfg, n_layers=5, attn_every=2)
    if window:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S, S0 = 2, 24, 18
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.arch_type == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model)) * 0.02
    logits_full, _ = M.forward(params, cfg, rules, batch)
    b0 = dict(batch)
    b0["tokens"] = tok[:, :S0]
    last, cache, _ = M.prefill(params, cfg, rules, b0, cache_len=S)
    errs = [np.abs(np.asarray(last) - np.asarray(logits_full[:, S0-1])).max()]
    for t in range(S0, S):
        lg, cache = M.decode_step(params, cfg, rules, cache, tok[:, t],
                                  jnp.int32(t))
        errs.append(np.abs(np.asarray(lg) -
                           np.asarray(logits_full[:, t])).max())
    assert max(errs) < 2e-3, errs


def test_ragged_decode_matches_scalar(rules):
    """Vector-position decode (continuous batching) == scalar-pos decode
    when all requests happen to be aligned."""
    cfg = reduced(get_config("qwen2.5-3b"))
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    B, S0 = 2, 12
    tok = jax.random.randint(key, (B, S0 + 1), 0, cfg.vocab_size)
    _, cache, _ = M.prefill(params, cfg, rules, {"tokens": tok[:, :S0]},
                            cache_len=S0 + 4)
    lg_s, _ = M.decode_step(params, cfg, rules, cache, tok[:, S0],
                            jnp.int32(S0))
    pos_v = jnp.full((B,), S0, jnp.int32)
    lg_v, _ = M.decode_step(params, cfg, rules, cache, tok[:, S0], pos_v,
                            lengths=pos_v + 1)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               atol=2e-4, rtol=1e-4)
