"""§Perf variant correctness: performance variants must be
numerics-preserving (same function, different layout/schedule)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.input_specs import SHAPES, adjusted_cfg, apply_variant
from repro.models import model as M


def _setup(name="internlm2-1.8b", seed=0):
    cfg = reduced(get_config(name))
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    tok = jax.random.randint(key, (2, 24), 0, cfg.vocab_size)
    return cfg, params, {"tokens": tok}


def test_kv_repeat_preserves_forward(rules):
    cfg, params, batch = _setup()
    base, _ = M.forward(params, cfg, rules, batch)
    cfg2 = dataclasses.replace(cfg, attn_kv_repeat=True)
    var, _ = M.forward(params, cfg2, rules, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(var),
                               atol=2e-4, rtol=1e-4)


def test_attn_row_parallel_preserves_forward(rules):
    cfg, params, batch = _setup()
    base, _ = M.forward(params, cfg, rules, batch)
    cfg2 = dataclasses.replace(cfg, attn_row_parallel=True)
    # same param SHAPES (only logical sharding axes differ)
    sds_a = jax.tree.map(lambda s: s.shape, M.param_sds(cfg))
    sds_b = jax.tree.map(lambda s: s.shape, M.param_sds(cfg2))
    assert sds_a == sds_b
    var, _ = M.forward(params, cfg2, rules, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(var),
                               atol=2e-4, rtol=1e-4)


def test_apply_variant_table():
    cfg = get_config("arctic-480b")
    v = apply_variant(cfg, "head_pad64_kv_repeat")
    assert v.n_heads == 64 and v.attn_kv_repeat
    assert apply_variant(cfg, None) is cfg
    with pytest.raises(ValueError):
        apply_variant(cfg, "bogus")


def test_adjusted_cfg_long500k_sliding_window():
    shape = SHAPES["long_500k"]
    dense = adjusted_cfg("phi3-mini-3.8b", shape)
    assert dense.sliding_window == 8192
    ssm = adjusted_cfg("mamba2-1.3b", shape)
    assert ssm.sliding_window is None          # native sub-quadratic


def test_padded_vocab_logits_masked(rules):
    """Archs with non-divisible vocab get padded columns masked to -inf."""
    cfg = dataclasses.replace(reduced(get_config("mamba2-1.3b")),
                              vocab_size=500)   # padded_vocab = 512
    assert cfg.padded_vocab == 512
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.zeros((1, 8), jnp.int32)
    logits, _ = M.forward(params, cfg, rules, {"tokens": tok})
    assert logits.shape[-1] == 512
    assert np.all(np.asarray(logits[..., 500:]) < -1e29)
    # and decode surface slices them off
    last, cache, _ = M.prefill(params, cfg, rules, {"tokens": tok},
                               cache_len=12)
    assert last.shape == (1, 500)
