"""Unit tests for the paper-core: census, roofline, BCA, replication
planner, simulator, and the paper-claims numbers they reproduce."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_config
from repro.core import (H100_PAPER, TPU_V5E, BatchingConfigurationAdvisor,
                        HloCensus, ReplicationPlanner, decode_curves,
                        max_batch_for, replication_sweep, roofline_report,
                        simulate_decode, slo_from_reference)
from repro.core.intensity import intensity_sweep
from repro.core.perfmodel import HostOverhead


def test_census_counts_scan_trip():
    def body(c, w):
        return jnp.tanh(c @ w), ()

    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    cen = HloCensus(comp.as_text()).census()
    expected = 2 * 64 * 64 * 64 * 7
    assert expected <= cen.flops <= expected * 1.2


def test_census_collectives():
    import jax.sharding as jsh
    devs = jax.devices()
    if len(devs) < 2:
        # single-device CPU in tests: collective census covered by dryrun
        return
    assert True


def test_roofline_report_terms():
    from repro.core.analysis import OpCensus, ClassCost
    c = OpCensus(flops=197e12, bytes=819e9, coll_bytes=50e9,
                 per_class={"matmul": ClassCost(197e12, 819e9, 0)},
                 per_collective={})
    r = roofline_report(c, TPU_V5E, chips=1, model_flops=100e12)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert 0.5 < r.useful_ratio < 0.51


def test_paper_bca_opt13b_strict():
    """Paper Table IV: OPT-1.3B strict SLO gives B_opt=96 with ~16% of the
    KV cache. Our modeled reproduction must land in that neighbourhood."""
    cfg = get_config("opt-1.3b")
    hw = H100_PAPER
    mb = min(max_batch_for(cfg, hw, ctx=331), 512)
    curves = decode_curves(cfg, hw, ctx=331, max_batch=mb)
    slo = slo_from_reference(curves, 32, 2.0)
    res = BatchingConfigurationAdvisor(curves, slo_s=slo, eps=0.1).solve()
    assert 48 <= res.b_opt <= 192, res.b_opt
    assert res.kv_fraction < 0.35
    assert res.throughput_retained > 0.5


def test_intensity_fig1_shape():
    cfg = get_config("opt-1.3b")
    pts = intensity_sweep(cfg, H100_PAPER, ctx=331, batches=[1, 512])
    ai1, aiM = pts[0].ai["attention"], pts[1].ai["attention"]
    assert abs(ai1 - aiM) / ai1 < 0.01           # constant in batch
    assert 0.25 < ai1 < 4.0                       # paper: 0.5-1 FLOP/B
    assert pts[1].ai["matmul"] > 50 * pts[0].ai["matmul"]


def test_replication_planner_and_sim():
    cfg = get_config("opt-1.3b")
    hw = H100_PAPER
    plan = ReplicationPlanner(hw, cfg, ctx=331).plan(96, max_replicas=4)
    assert plan.n_replicas >= 2
    assert plan.total_bytes <= plan.capacity_bytes
    sweep = replication_sweep(cfg, hw, batch=96, ctx=331, max_replicas=4)
    # paper: replication increases throughput AND DRAM utilization
    assert sweep[1].throughput_tok_s > sweep[0].throughput_tok_s * 1.1
    assert sweep[-1].dram_utilization > sweep[0].dram_utilization
    # and individual step latency (ITL) gets worse, as the paper reports
    assert sweep[-1].itl_s > sweep[0].itl_s


def test_replication_gain_matches_paper_band():
    """Paper: +33.7% for OPT-1.3B (4 replicas) vs MAX single replica."""
    cfg = get_config("opt-1.3b")
    hw = H100_PAPER
    host = HostOverhead()
    mb = min(max_batch_for(cfg, hw, ctx=331), 512)
    t_max = simulate_decode(cfg, hw, batch=mb, n_replicas=1, ctx=331,
                            host=host).throughput_tok_s
    t_rep = simulate_decode(cfg, hw, batch=96, n_replicas=4, ctx=331,
                            host=host).throughput_tok_s
    gain = t_rep / t_max - 1
    assert 0.10 < gain < 0.80, gain


def test_slice_mesh():
    from repro.core.replication import slice_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    subs = slice_mesh(mesh, 1)
    assert len(subs) == 1
