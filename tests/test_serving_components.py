"""Serving-layer satellites: EngineConfig construction-time validation,
burst/ramp arrival patterns, and TTFT/percentile metrics collection."""
import numpy as np
import pytest

from repro.serving import EngineConfig, Percentiles, sharegpt_like
from repro.serving.metrics import collect
from repro.serving.workload import Request, arrival_times


# ----------------------------------------------------- EngineConfig -----
def test_engine_config_accepts_valid():
    EngineConfig(max_batch=4, block_size=8, kv_pool_tokens=4096,
                 max_model_len=256)


@pytest.mark.parametrize("kw,msg", [
    (dict(kv_pool_tokens=100, block_size=16), "divisible"),
    (dict(kv_pool_tokens=8, block_size=16), "divisible"),
    (dict(kv_pool_tokens=512, max_model_len=1024), "max_model_len"),
    (dict(max_batch=0), "max_batch"),
    (dict(block_size=0), "block_size"),
    (dict(prefill_bucket=0), "prefill_bucket"),
    (dict(decode_mode="telepathic"), "decode_mode"),
])
def test_engine_config_rejects(kw, msg):
    base = dict(max_batch=4, block_size=16, kv_pool_tokens=4096,
                max_model_len=256)
    base.update(kw)
    with pytest.raises(ValueError, match=msg):
        EngineConfig(**base)


# --------------------------------------------------- arrival patterns ---
def test_poisson_arrivals_average_the_rate():
    t = arrival_times(400, 10.0, pattern="poisson", seed=0)
    assert np.all(np.diff(t) > 0)
    assert 0.05 < float(np.mean(np.diff(t))) < 0.2       # ~1/rate


def test_burst_arrivals_group_simultaneously():
    t = arrival_times(16, 8.0, pattern="burst", seed=1, burst_size=4)
    assert len(t) == 16
    groups = np.unique(t)
    assert len(groups) == 4                  # 4 bursts of 4
    for g in groups:
        assert int((t == g).sum()) == 4
    assert np.all(np.diff(t) >= 0)
    # long-run rate preserved within a loose factor
    assert t[-1] == pytest.approx(16 / 8.0, rel=2.0)


def test_ramp_arrivals_densify_over_time_at_nominal_rate():
    t = arrival_times(4000, 10.0, pattern="ramp", seed=2)
    gaps = np.diff(t)
    assert np.all(gaps >= 0)
    early, late = gaps[:1500].mean(), gaps[-1500:].mean()
    assert late < early                      # rate ramps up
    # harmonic-mean normalization keeps the long-run rate on target
    assert 4000 / t[-1] == pytest.approx(10.0, rel=0.1)
    assert arrival_times(1, 10.0, pattern="ramp", seed=3)[0] > 0


def test_arrival_times_validation():
    with pytest.raises(ValueError, match="pattern"):
        arrival_times(4, 1.0, pattern="tsunami")
    with pytest.raises(ValueError, match="rate"):
        arrival_times(4, 0.0)
    with pytest.raises(ValueError, match="burst_size"):
        arrival_times(4, 1.0, pattern="burst", burst_size=0)
    # patterns must fail loudly even when no arrival_rate reaches
    # arrival_times (a silent t=0 batch workload is a footgun)
    with pytest.raises(ValueError, match="pattern"):
        sharegpt_like(4, 100, arrival_pattern="tsunami")
    with pytest.raises(ValueError, match="requires.*arrival_rate"):
        sharegpt_like(4, 100, arrival_pattern="burst")


def test_sharegpt_like_patterns_keep_lengths_stable():
    """The new patterns draw arrivals from a separate rng, so turning
    them on must not perturb the token/length stream for a given seed.
    (Legacy poisson interleaves arrival draws with length draws and is
    kept bitwise-identical to the pre-pattern generator instead.)"""
    kw = dict(seed=5, mean_in=20, mean_out=30, max_len=128)
    plain = sharegpt_like(8, 1000, **kw)
    poisson = sharegpt_like(8, 1000, arrival_rate=4.0, **kw)
    burst = sharegpt_like(8, 1000, arrival_rate=4.0,
                          arrival_pattern="burst", burst_size=4, **kw)
    ramp = sharegpt_like(8, 1000, arrival_rate=4.0,
                         arrival_pattern="ramp", **kw)
    assert all(r.arrival_s == 0.0 for r in plain)
    assert np.all(np.diff([r.arrival_s for r in poisson]) > 0)
    for variant in (burst, ramp):
        assert [r.prompt_len for r in variant] == \
            [r.prompt_len for r in plain]
        assert [r.max_new_tokens for r in variant] == \
            [r.max_new_tokens for r in plain]
    assert [np.array_equal(a.prompt, b.prompt)
            for a, b in zip(burst, plain)] == [True] * 8
    bursts = {r.arrival_s for r in burst}
    assert len(bursts) == 2 and all(t > 0 for t in bursts)


# ------------------------------------------------------- percentiles ----
def test_percentiles_from_samples():
    assert Percentiles.from_samples([]) == Percentiles()
    samples = np.arange(1, 101) / 100.0
    p = Percentiles.from_samples(samples)
    assert p.p50 == pytest.approx(np.percentile(samples, 50))
    assert p.p95 == pytest.approx(np.percentile(samples, 95))
    assert p.p99 == pytest.approx(np.percentile(samples, 99))
    assert "p95" in p.row()


def test_collect_reports_ttft_and_tails():
    reqs = []
    for i in range(4):
        r = Request(req_id=i, prompt=np.arange(10, dtype=np.int32),
                    max_new_tokens=5, arrival_s=float(i))
        r.t_first_token = i + 0.5           # TTFT = 0.5s each
        r.t_done = i + 2.0                  # E2E  = 2.0s each
        r.generated = 5
        reqs.append(r)
    unfinished = Request(req_id=9, prompt=np.arange(3, dtype=np.int32),
                         max_new_tokens=5)
    itl = [0.01, 0.02, 0.03, 0.04]
    m = collect(reqs + [unfinished], wall_s=10.0, itl_samples=itl,
                max_kv_fraction=0.5, batch_samples=[2, 2])
    assert m.n_completed == 4
    assert m.ttft_s == pytest.approx(0.5)
    assert m.ttft.p50 == pytest.approx(0.5)
    assert m.e2e_s == pytest.approx(2.0)
    assert m.e2e.p99 == pytest.approx(2.0)
    assert m.itl.p50 == pytest.approx(np.percentile(itl, 50))
    assert m.total_tokens == 4 * (10 + 5)
    assert "TTFT" in m.latency_row()
