"""Scheduler/executor split with double-buffered overlapped steps:
``EngineConfig.overlap=True`` must be **bit-identical** to the
synchronous loop (tokens, finish reasons, per-request metrics) across
greedy and sampled decode, chunked prefill, the prefix cache,
pool-pressure preemption, a 2-replica cluster, and a kill-1-of-2 fault
redrive — while mid-overlap abort/deadline expiry must reclaim the KV of
an already-dispatched step without corrupting survivors. Also pins the
cluster's event-driven wakeups (an idle threaded cluster burns no engine
steps) and the asyncio facade's equivalence to the sync facade."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (AsyncServingAPI, ContinuousBatchingEngine,
                           EngineConfig, FaultInjector, FaultSpec,
                           ReplicatedCluster, Request, SamplingParams,
                           ServingAPI, StepFunctions, sharegpt_like,
                           shared_prefix_workload)
from repro.serving.workload import (FINISH_ABORT, FINISH_DEADLINE,
                                    FINISH_LENGTH, FINISH_STOP)

SERVED = (FINISH_LENGTH, FINISH_STOP)
SAMPLED = SamplingParams(temperature=0.9, top_k=40, seed=11)


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


def _engine(setup, **kw):
    _, params, model, steps = setup
    return ContinuousBatchingEngine(model, params, _ecfg(**kw), steps=steps)


def _wl(cfg, n=5, seed=2, mean_out=8, **kw):
    return sharegpt_like(n, cfg.vocab_size, seed=seed, mean_in=12,
                         mean_out=mean_out, max_len=64, sigma=0.4, **kw)


def _request_metrics(reqs):
    """The per-request record bit-identity is judged on: exact output
    tokens, finish reason, token count, and TTFT presence. (Wall-clock
    values legitimately differ between the two loops.)"""
    return [(list(map(int, r.output_tokens)), r.finish_reason,
             len(r.output_tokens), r.t_first_token is not None)
            for r in reqs]


def _run_both(setup, wl_fn, **ecfg_kw):
    """Run the same workload through the sync and overlapped loops on
    fresh engines; returns (sync_metrics, overlap_metrics, engines)."""
    out, engines = {}, {}
    for overlap in (False, True):
        eng = _engine(setup, overlap=overlap, **ecfg_kw)
        reqs = wl_fn()
        eng.run(reqs)
        assert all(r.t_done is not None for r in reqs)
        out[overlap] = _request_metrics(reqs)
        engines[overlap] = eng
    return out[False], out[True], engines


# --------------------------------------------------------- bit identity --
def test_overlap_bit_identical_greedy(setup):
    cfg = setup[0]
    sync, over, engines = _run_both(setup, lambda: _wl(cfg))
    assert over == sync
    # the overlapped engine actually overlapped: it ran through the
    # executor and left no in-flight residue behind
    assert engines[True].ecfg.overlap
    assert not engines[True]._executor._inflight
    assert not engines[True]._executor._chain


def test_overlap_bit_identical_sampled(setup):
    cfg = setup[0]
    sync, over, _ = _run_both(
        setup, lambda: _wl(cfg, seed=7, sampling=SAMPLED))
    assert any(m[1] in SERVED for m in sync)
    assert over == sync


def test_overlap_bit_identical_chunked_prefill(setup):
    cfg = setup[0]
    sync, over, engines = _run_both(
        setup, lambda: _wl(cfg, seed=4, mean_out=6),
        prefill_chunk_tokens=16)
    assert engines[True].chunking
    assert over == sync


def test_overlap_bit_identical_prefix_cache(setup):
    cfg = setup[0]
    wl = lambda: shared_prefix_workload(          # noqa: E731
        2, 3, cfg.vocab_size, prefix_len=24, suffix_len=8,
        max_new_tokens=6, seed=3)
    sync, over, engines = _run_both(setup, wl, prefix_cache=True)
    assert engines[True].prefix is not None
    assert over == sync


def test_overlap_bit_identical_across_preemption(setup):
    """Starved pool: recompute-style preemption must replay the same
    tokens under overlap, even though the overlapped loop commits (and
    therefore frees finished requests' KV) one plan later."""
    cfg = setup[0]
    wl = lambda: sharegpt_like(6, cfg.vocab_size, seed=11,  # noqa: E731
                               mean_in=20, mean_out=36, max_len=60,
                               sigma=0.1, sampling=SAMPLED)
    sync, over, engines = _run_both(setup, wl, max_batch=6,
                                    kv_pool_tokens=256, max_model_len=96)
    assert engines[True].preemptions > 0, \
        "workload was meant to force preemption under overlap"
    assert over == sync


# ---------------------------------------- mid-overlap abort / deadline --
def test_mid_overlap_abort_reclaims_dispatched_step(setup):
    """Abort a request while the executor holds a dispatched-not-yet-
    committed step for it: the speculative token must be discarded, its
    KV reclaimed, and every surviving request must stay bit-identical
    to the synchronous loop."""
    cfg = setup[0]
    baseline = _wl(cfg, mean_out=16)
    _engine(setup).run(baseline)

    eng = _engine(setup, overlap=True)
    reqs = _wl(cfg, mean_out=16)
    for r in reqs:
        eng.add_request(r)
    victim = reqs[0]
    aborted = False
    now = 0.0
    while eng.busy:
        eng.step(now)
        now += 1e-3
        if not aborted and len(victim.state.output_tokens) >= 3:
            # the executor has already dispatched the *next* token for
            # the victim at this point (double-buffered: one in flight)
            assert eng._executor._inflight, \
                "expected an in-flight step at abort time"
            assert eng.abort(victim.req_id, now)
            aborted = True
            n_at_abort = len(victim.state.output_tokens)
    assert aborted
    assert victim.finish_reason == FINISH_ABORT
    # no speculative token from the invalidated in-flight step landed
    assert len(victim.state.output_tokens) == n_at_abort
    assert list(victim.state.output_tokens) == \
        list(baseline[0].output_tokens)[:n_at_abort]
    # KV fully reclaimed once the engine drains
    assert eng.pool.manager.used_fraction == 0.0
    assert not eng._executor._inflight and not eng._executor._chain
    # survivors unaffected
    assert _request_metrics(reqs[1:]) == _request_metrics(baseline[1:])


def test_mid_overlap_deadline_expiry_reclaims_kv(setup):
    """A deadline that expires mid-decode must finish the request
    ``"deadline"`` under overlap, discard its dispatched step, and
    leave survivors bit-identical to the synchronous loop."""
    cfg = setup[0]
    mk = lambda: _wl(cfg, mean_out=16)            # noqa: E731

    def with_deadline(reqs):
        import dataclasses
        return [Request(req_id=r.req_id, prompt=r.prompt,
                        arrival_s=r.arrival_s,
                        max_new_tokens=r.max_new_tokens,
                        sampling=dataclasses.replace(
                            r.sampling, deadline_s=0.004)
                        if r.req_id == 0 else r.sampling)
                for r in reqs]

    outs = {}
    for overlap in (False, True):
        eng = _engine(setup, overlap=overlap)
        reqs = with_deadline(mk())
        for r in reqs:
            eng.add_request(r)
        # deterministic simulated clock: one millisecond per step, so
        # the deadline trips at the same plan number in both modes
        now = 0.0
        while eng.busy:
            eng.step(now)
            now += 1e-3
        assert reqs[0].finish_reason == FINISH_DEADLINE
        assert all(r.finish_reason in SERVED for r in reqs[1:])
        assert eng.pool.manager.used_fraction == 0.0
        outs[overlap] = _request_metrics(reqs[1:])
    assert outs[True] == outs[False]


# ----------------------------------------------------------- cluster --
def test_overlap_cluster_bit_identical(setup):
    cfg = setup[0]
    outs = {}
    for overlap in (False, True):
        engines = [_engine(setup, overlap=overlap) for _ in range(2)]
        cluster = ReplicatedCluster(engines, mode="sync")
        reqs = _wl(cfg, n=6, seed=9, mean_out=10)
        m = cluster.run(reqs)
        assert m.completed == 6
        outs[overlap] = _request_metrics(reqs)
    assert outs[True] == outs[False]


def test_overlap_kill_one_of_two_redrive_bit_identical(setup):
    """Replica death mid-overlap: quarantine drops the dead replica's
    in-flight dispatched step (Executor.reset) and the redrive
    regenerates the exact fault-free tokens on the survivor."""
    cfg = setup[0]
    baseline = _wl(cfg, n=6, seed=9, mean_out=10)
    ReplicatedCluster([_engine(setup, overlap=True),
                       _engine(setup, overlap=True)],
                      mode="sync").run(baseline)
    assert all(r.finish_reason in SERVED for r in baseline)

    inj = FaultInjector([FaultSpec("kill", replica=1, step=4)])
    cluster = ReplicatedCluster([_engine(setup, overlap=True),
                                 _engine(setup, overlap=True)],
                                mode="sync", faults=inj)
    reqs = _wl(cfg, n=6, seed=9, mean_out=10)
    m = cluster.run(reqs)
    assert len(inj.fired) == 1
    assert m.faults == 1 and m.redriven > 0 and m.lost == 0
    assert m.completed == 6
    dead = cluster.replicas[1].engine
    assert not dead._executor._inflight and not dead._executor._chain
    assert _request_metrics(reqs) == _request_metrics(baseline)


def test_idle_cluster_burns_no_steps(setup):
    """Event-driven wakeups: with every arrival still in the future, the
    threaded replica loops must park on the work condition variable —
    ``step_count`` measures work, not polling."""
    cfg = setup[0]
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="thread")
    base = _wl(cfg, n=4, seed=5)
    reqs = [Request(req_id=r.req_id, prompt=r.prompt, arrival_s=0.4,
                    sampling=r.sampling,
                    max_new_tokens=r.max_new_tokens) for r in base]
    samples = []

    def watcher():
        t0 = time.monotonic()
        while time.monotonic() - t0 < 0.3:
            samples.append(sum(rep.engine.step_count
                               for rep in cluster.replicas))
            time.sleep(0.02)

    w = threading.Thread(target=watcher)
    w.start()
    m = cluster.run(reqs)
    w.join()
    assert samples and max(samples) == 0, \
        f"idle cluster burned steps: {samples}"
    assert m.completed == len(reqs)


# ------------------------------------------------------- async facade --
def test_async_api_matches_sync_facade(setup):
    """AsyncServingAPI (pump thread + per-handle queues) must emit the
    same tokens as the cooperative sync facade — here on top of an
    *overlapped* engine, so the whole stack composes."""
    import asyncio

    cfg = setup[0]
    prompts = [list(map(int, np.asarray(r.prompt)))
               for r in _wl(cfg, n=4, seed=3)]

    sync_api = ServingAPI(_engine(setup, overlap=True))
    for p in prompts:
        sync_api.submit(p)
    sync_outs = sync_api.drain()

    async def main():
        api = AsyncServingAPI(_engine(setup, overlap=True))
        handles = [await api.submit(p) for p in prompts]

        async def consume(h):
            toks = []
            async for ev in api.stream(h):
                toks.extend(ev.new_token_ids)
                if ev.finished:
                    return toks, ev.finish_reason
            return toks, None

        streamed = await asyncio.gather(*(consume(h) for h in handles))
        outs = await api.drain()
        await api.aclose()
        return streamed, outs

    streamed, outs = asyncio.run(main())
    assert set(outs) == set(sync_outs)
    for rid in outs:
        assert outs[rid].token_ids == sync_outs[rid].token_ids
        assert outs[rid].finish_reason == sync_outs[rid].finish_reason
    # streamed deltas reassemble to the same cumulative outputs
    for (toks, reason), h_rid in zip(streamed, sorted(outs)):
        assert tuple(toks) == outs[h_rid].token_ids
        assert reason == outs[h_rid].finish_reason


def test_async_api_abort_terminates_stream(setup):
    import asyncio

    cfg = setup[0]
    prompts = [list(map(int, np.asarray(r.prompt)))
               for r in _wl(cfg, n=2, seed=3, mean_out=16)]

    async def main():
        async with AsyncServingAPI(_engine(setup, overlap=True)) as api:
            h0 = await api.submit(prompts[0])
            h1 = await api.submit(prompts[1])
            # let a few tokens land, then abort the first stream
            seen = []
            async for ev in api.stream(h0):
                seen.extend(ev.new_token_ids)
                if ev.finished:
                    return seen, ev.finish_reason, None
                if len(seen) >= 2:
                    await api.abort(h0)
            # stream already ended via finished event inside the loop
            outs = await api.drain()
            return seen, outs[h0.req_id].finish_reason, \
                outs[h1.req_id].finish_reason

    seen, reason0, reason1 = asyncio.run(main())
    assert reason0 == FINISH_ABORT
    assert reason1 in SERVED or reason1 is None
