"""Fault tolerance: deterministic fault injection, replica quarantine +
redrive (bit-identical outputs on survivors), respawn, poison-request
eviction, redrive budgets, watchdog wedge detection, and the prompt
fail-fast path when recovery is disabled."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           FaultInjector, FaultSpec, InjectedFault,
                           ReplicatedCluster, Request, SamplingParams,
                           ServingAPI, StepFunctions, parse_fault,
                           sharegpt_like)
from repro.serving.engine import RequestTooLarge
from repro.serving.workload import FINISH_FAILED, FINISH_LENGTH, FINISH_STOP


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


def _engine(setup, **kw):
    _, params, model, steps = setup
    return ContinuousBatchingEngine(model, params, _ecfg(**kw), steps=steps)


def _wl(cfg, n=4, seed=2, mean_out=6):
    return sharegpt_like(n, cfg.vocab_size, seed=seed, mean_in=12,
                         mean_out=mean_out, max_len=48, sigma=0.4)


def _outputs(reqs):
    return [list(r.output_tokens) for r in reqs]


SERVED = (FINISH_LENGTH, FINISH_STOP)


# ---------------------------------------------------------- fault specs --
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="explode", replica=0, step=1)
    with pytest.raises(ValueError, match="replica"):
        FaultSpec(kind="kill", replica=-1, step=1)
    with pytest.raises(ValueError, match="step"):
        FaultSpec(kind="kill", replica=0, step=0)
    with pytest.raises(ValueError, match="seconds"):
        FaultSpec(kind="delay", replica=0, step=1, seconds=-1)


def test_parse_fault_cli_shape():
    spec = parse_fault("replica=1,step=50")
    assert spec == FaultSpec(kind="kill", replica=1, step=50)
    spec = parse_fault("replica=0, step=3, kind=delay, seconds=0.25")
    assert spec.kind == "delay" and spec.seconds == 0.25
    with pytest.raises(ValueError, match="unknown"):
        parse_fault("replica=0,step=1,color=red")
    with pytest.raises(ValueError, match="needs at least"):
        parse_fault("step=1")


def test_injector_fires_once_at_or_after_step():
    inj = FaultInjector([FaultSpec("kill", replica=0, step=3)])
    inj.on_step(0, 1)
    inj.on_step(1, 5)                     # other replica: never fires
    with pytest.raises(InjectedFault):
        inj.on_step(0, 4)                 # at-or-after semantics
    assert not inj.pending and len(inj.fired) == 1
    inj.on_step(0, 5)                     # fires exactly once
    inj.reset()
    assert inj.pending == (FaultSpec("kill", replica=0, step=3),)


def test_random_kill_seeded():
    a = FaultInjector.random_kill(4, 100, seed=7)
    b = FaultInjector.random_kill(4, 100, seed=7)
    assert a.specs == b.specs
    spec = a.specs[0]
    assert spec.kind == "kill" and 0 <= spec.replica < 4 \
        and 1 <= spec.step <= 100


def test_alloc_fail_fault_skips_one_admission(setup):
    eng = _engine(setup)
    eng.faults = FaultInjector([FaultSpec("alloc-fail", replica=0, step=1)])
    req = _wl(setup[0], n=1, seed=5)[0]
    eng.add_request(req)
    eng.step(0.0)
    assert len(eng.waiting) == 1          # admission stolen, request waits
    eng.step(0.0)
    assert not eng.waiting                # admitted next step, no crash
    while eng.busy:
        eng.step(0.0)
    assert req.finish_reason in SERVED


# ------------------------------------------------------- kill + redrive --
def test_kill_recovery_sync_bit_identical(setup):
    cfg = setup[0]
    baseline = _wl(cfg, n=6, seed=9, mean_out=10)
    ReplicatedCluster([_engine(setup), _engine(setup)],
                      mode="sync").run(baseline)
    assert all(r.finish_reason in SERVED for r in baseline)

    inj = FaultInjector([FaultSpec("kill", replica=1, step=4)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj)
    reqs = _wl(cfg, n=6, seed=9, mean_out=10)
    m = cluster.run(reqs)
    assert len(inj.fired) == 1
    assert m.faults == 1 and m.redriven > 0 and m.lost == 0
    assert m.completed == 6
    # every redriven request regenerated the exact fault-free tokens
    assert _outputs(reqs) == _outputs(baseline)
    assert all(r.finish_reason in SERVED for r in reqs)
    stats = m.per_replica[1]
    assert not stats.healthy and stats.faults == 1
    assert stats.availability < 1.0 and m.availability < 1.0
    assert not cluster.replicas[1].healthy
    assert "faults:" in m.summary()


def test_kill_recovery_threaded_bit_identical(setup):
    cfg = setup[0]
    baseline = _wl(cfg, n=6, seed=9, mean_out=10)
    ReplicatedCluster([_engine(setup), _engine(setup)],
                      mode="sync").run(baseline)

    inj = FaultInjector([FaultSpec("kill", replica=1, step=4)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="thread", faults=inj)
    reqs = _wl(cfg, n=6, seed=9, mean_out=10)
    m = cluster.run(reqs)
    assert m.faults == 1 and m.completed == 6 and m.lost == 0
    assert all(r.finish_reason in SERVED for r in reqs)
    # same tokens as the fault-free run for every non-lost request
    # (timed dispatch may route differently, but decode is per-request
    # deterministic, so outputs — not placements — must match)
    assert _outputs(reqs) == _outputs(baseline)


def test_kill_recovery_sampled_bit_identical(setup):
    cfg = setup[0]

    def mk():
        rng = np.random.default_rng(17)
        return [Request(req_id=i,
                        prompt=rng.integers(0, cfg.vocab_size, 10,
                                            dtype=np.int32),
                        arrival_s=0.0,
                        sampling=SamplingParams(temperature=0.8,
                                                top_k=20, seed=100 + i,
                                                max_new_tokens=8))
                for i in range(4)]

    baseline = mk()
    ReplicatedCluster([_engine(setup), _engine(setup)],
                      mode="sync").run(baseline)

    inj = FaultInjector([FaultSpec("kill", replica=0, step=3)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj)
    reqs = mk()
    m = cluster.run(reqs)
    assert m.completed == 4 and m.redriven > 0
    # counter-based per-request RNG: redriven sampled decode replays the
    # same stream positions, so even temperature>0 outputs are identical
    assert _outputs(reqs) == _outputs(baseline)


def test_respawn_returns_replica_to_service(setup):
    inj = FaultInjector([FaultSpec("kill", replica=1, step=3)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj, respawn=True)
    reqs = _wl(setup[0], n=8, seed=21, mean_out=10)
    m = cluster.run(reqs)
    assert m.faults == 1 and m.completed == 8 and m.lost == 0
    rep = cluster.replicas[1]
    assert rep.healthy and rep.downtime >= 0.0
    stats = m.per_replica[1]
    assert stats.healthy and stats.faults == 1
    # the respawned engine is a fresh build sharing the compiled steps
    assert rep.engine is not None and rep.engine.replica_id == 1
    assert all(r.finish_reason in SERVED for r in reqs)


def test_poison_request_evicted_not_fatal(setup):
    """Degrade-don't-die: a request that can never fit the pool fails
    alone; the replica keeps serving everyone else. (On a bare engine
    the same request is still a hard RuntimeError — see
    test_chunked_prefill's pool-exhaustion test.)"""
    cfg = setup[0]
    eng = _engine(setup, kv_pool_tokens=128, max_model_len=128,
                  prefill_bucket=128)
    rng = np.random.default_rng(3)
    poison = Request(req_id=99,
                     prompt=rng.integers(0, cfg.vocab_size, 120,
                                         dtype=np.int32),
                     arrival_s=0.0,
                     sampling=SamplingParams(max_new_tokens=4))
    small = [Request(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size, 6,
                                         dtype=np.int32),
                     arrival_s=0.0,
                     sampling=SamplingParams(max_new_tokens=4))
             for i in range(3)]
    cluster = ReplicatedCluster([eng], mode="sync")
    m = cluster.run([poison] + small)
    assert poison.finish_reason == FINISH_FAILED
    assert all(r.finish_reason in SERVED for r in small)
    assert cluster.replicas[0].healthy
    assert m.lost == 1 and m.faults == 1 and m.completed == 4
    assert m.finish_reasons[FINISH_FAILED] == 1


def test_request_too_large_is_runtime_error(setup):
    """The bare-engine contract is unchanged: RequestTooLarge subclasses
    RuntimeError with the legacy message."""
    assert issubclass(RequestTooLarge, RuntimeError)
    exc = RequestTooLarge("KV pool exhausted: nope", 7)
    assert exc.req_id == 7


def test_all_replicas_dead_requests_fail_without_hang(setup):
    inj = FaultInjector([FaultSpec("kill", replica=0, step=2),
                         FaultSpec("kill", replica=1, step=2)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj)
    reqs = _wl(setup[0], n=6, seed=31, mean_out=20)
    m = cluster.run(reqs)                 # completes, never raises/hangs
    assert m.faults == 2
    assert all(r.t_done is not None for r in reqs)
    assert any(r.finish_reason == FINISH_FAILED for r in reqs)
    assert m.completed == 6
    assert not any(rep.healthy for rep in cluster.replicas)
    assert m.availability < 1.0


def test_redrive_budget_caps_retries(setup):
    """max_redrives=0: stranded requests fail immediately instead of
    redriving — the budget floor."""
    inj = FaultInjector([FaultSpec("kill", replica=1, step=3)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj, max_redrives=0)
    reqs = _wl(setup[0], n=6, seed=9, mean_out=10)
    m = cluster.run(reqs)
    assert m.redriven == 0 and m.lost > 0
    assert all(r.t_done is not None for r in reqs)
    # replica 0's requests were untouched by replica 1's death
    assert any(r.finish_reason in SERVED for r in reqs)


def test_recover_false_threaded_stops_promptly_and_stamps(setup):
    """Legacy fail-fast semantics, minus the drain spin: on a replica
    error the feeder signals surviving loops and every request that will
    never be served carries an explicit "failed" reason."""
    inj = FaultInjector([FaultSpec("kill", replica=1, step=2)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="thread", faults=inj, recover=False)
    reqs = _wl(setup[0], n=6, seed=41, mean_out=30)
    with pytest.raises(InjectedFault):
        cluster.run(reqs)
    # every request is terminal: served before the crash, or failed
    assert all(r.t_done is not None for r in reqs)
    assert any(r.finish_reason == FINISH_FAILED for r in reqs)


def test_watchdog_trips_on_delayed_step(setup):
    inj = FaultInjector([FaultSpec("delay", replica=0, step=2,
                                   seconds=0.05)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj, watchdog_s=0.01)
    reqs = _wl(setup[0], n=6, seed=51, mean_out=10)
    m = cluster.run(reqs)
    assert m.watchdog_trips >= 1
    assert m.completed == 6
    assert all(r.finish_reason in SERVED for r in reqs)
    # wedge is advisory and self-heals: the replica is healthy at the end
    assert all(rep.healthy for rep in cluster.replicas)


def test_facade_pump_recovers_from_kill(setup):
    """Streaming path: a replica death under ServingAPI.submit/drain
    redrives onto the survivor and every handle finishes served."""
    inj = FaultInjector([FaultSpec("kill", replica=1, step=3)])
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync", faults=inj)
    api = ServingAPI(cluster)
    reqs = _wl(setup[0], n=4, seed=61, mean_out=8)
    handles = [api.submit(r) for r in reqs]
    outs = api.drain()
    assert len(outs) == 4
    assert cluster.redriven > 0
    for h in handles:
        assert h.done and h.finish_reason in SERVED
        assert list(outs[h.req_id].token_ids) \
            == list(h.request.output_tokens)
    assert api.metrics().faults == 1
