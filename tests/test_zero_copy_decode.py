"""Zero-copy paged decode: the engine's block-table data path must be
token-for-token identical to the legacy gather fallback, survive pool
exhaustion by preemption instead of crashing, and keep the paged pool's
slot bookkeeping sound."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kvcache.paged import PagedKVCache
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           sharegpt_like)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, rules, mode, reqs, **ecfg_kw):
    model = Model(cfg, rules)
    ecfg = EngineConfig(decode_mode=mode, **ecfg_kw)
    engine = ContinuousBatchingEngine(model, params, ecfg)
    engine.run(reqs)
    return engine


def test_paged_matches_gather_mixed_lengths(setup, rules):
    """The tentpole acceptance check: zero-copy and gather decode produce
    identical tokens on a mixed-length continuous-batching workload."""
    cfg, params = setup
    kw = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
              max_model_len=256, prefill_bucket=16)
    outs = {}
    for mode in ("paged", "gather"):
        reqs = sharegpt_like(6, cfg.vocab_size, seed=7, mean_in=14,
                             mean_out=10, max_len=64, sigma=0.6)
        eng = _run(cfg, params, rules, mode, reqs, **kw)
        assert eng.decode_mode == mode
        assert all(r.t_done is not None for r in reqs)
        outs[mode] = [r.output_tokens for r in reqs]
    assert outs["paged"] == outs["gather"]


def test_paged_matches_gather_moe_nonpow2_batch(rules):
    """MoE routing ranks tokens by batch position, so the padding rows the
    paged path appends can never evict a real token's expert slot; with the
    generous serve capacity factor the two modes stay token-identical even
    at a non-power-of-two batch (where expert capacity C differs)."""
    cfg = reduced(get_config("olmoe-1b-7b"))
    params = init_params(cfg, jax.random.PRNGKey(2))
    kw = dict(max_batch=3, block_size=8, kv_pool_tokens=4096,
              max_model_len=128, prefill_bucket=16)
    outs = {}
    for mode in ("paged", "gather"):
        reqs = sharegpt_like(4, cfg.vocab_size, seed=9, mean_in=10,
                             mean_out=6, max_len=40, sigma=0.4)
        _run(cfg, params, rules, mode, reqs, **kw)
        assert all(r.t_done is not None for r in reqs)
        outs[mode] = [r.output_tokens for r in reqs]
    assert outs["paged"] == outs["gather"]


def test_paged_decode_no_dense_gather_on_steady_state(setup, rules):
    """pool.gather / scatter_new_token stay off the paged decode path."""
    cfg, params = setup
    model = Model(cfg, rules)
    engine = ContinuousBatchingEngine(
        model, params, EngineConfig(max_batch=4, block_size=8,
                                    kv_pool_tokens=4096, max_model_len=128,
                                    prefill_bucket=16))
    calls = []
    orig_gather = engine.pool.gather
    orig_scatter = engine.pool.scatter_new_token
    engine.pool.gather = lambda *a, **k: (calls.append("gather"),
                                          orig_gather(*a, **k))[1]
    engine.pool.scatter_new_token = (
        lambda *a, **k: (calls.append("scatter"),
                         orig_scatter(*a, **k))[1])
    reqs = sharegpt_like(4, cfg.vocab_size, seed=5, mean_in=10, mean_out=6,
                         max_len=48, sigma=0.3)
    engine.run(reqs)
    assert calls == []
    assert all(r.t_done is not None for r in reqs)


def test_pool_exhaustion_preempts_instead_of_crashing(setup, rules):
    """Mid-decode block exhaustion must requeue the youngest running
    request (recompute-style), not raise 'KV pool exhausted'."""
    cfg, params = setup
    # pool small enough that admitted requests outgrow it while decoding:
    # admission needs prompt+1 (~3 blocks each), completion needs ~7.
    reqs = sharegpt_like(6, cfg.vocab_size, seed=11, mean_in=20,
                         mean_out=36, max_len=60, sigma=0.1)
    model = Model(cfg, rules)
    engine = ContinuousBatchingEngine(
        model, params, EngineConfig(max_batch=6, block_size=8,
                                    kv_pool_tokens=256, max_model_len=96,
                                    prefill_bucket=16))
    engine.run(reqs)
    assert all(r.t_done is not None for r in reqs)
    assert engine.preemptions > 0, "workload was meant to force preemption"
    # deterministic greedy decode: preempted-and-recomputed requests must
    # emit the same tokens as an undisturbed run with a roomy pool
    reqs2 = sharegpt_like(6, cfg.vocab_size, seed=11, mean_in=20,
                          mean_out=36, max_len=60, sigma=0.1)
    engine2 = ContinuousBatchingEngine(
        model, params, EngineConfig(max_batch=6, block_size=8,
                                    kv_pool_tokens=8192, max_model_len=96,
                                    prefill_bucket=16))
    engine2.run(reqs2)
    assert engine2.preemptions == 0
    for a, b in zip(reqs, reqs2):
        assert a.output_tokens == b.output_tokens, a.req_id


def test_release_without_gather_frees_slot(setup):
    """Regression for the _slot lazy-init hack: release() before any
    gather()/view() must actually free the dense-state slot."""
    cfg, _ = setup
    pool = PagedKVCache(cfg, num_blocks=8, block_size=8, max_batch=2)
    pool.manager.allocate(0, 8)
    pool._slot(0)
    assert len(pool._free_slots) == pool.max_batch - 1
    pool.release(0)
    assert len(pool._free_slots) == pool.max_batch
    assert pool.manager.tables == {}


def test_view_caches_device_tables(setup):
    """Steady-state decode (no allocator change) must not re-upload the
    block table; any allocation must invalidate the cache."""
    cfg, _ = setup
    pool = PagedKVCache(cfg, num_blocks=16, block_size=8, max_batch=2)
    pool.manager.allocate(0, 12)
    v1 = pool.view([0], [12], nb_pad=4, batch_pad=1)
    v2 = pool.view([0], [13], nb_pad=4, batch_pad=1)
    assert v1.tables is v2.tables
    pool.manager.append_token(0, 17)          # crosses a block boundary
    v3 = pool.view([0], [16], nb_pad=4, batch_pad=1)
    assert v3.tables is not v1.tables
    # padding row addresses the trash block and slot, length 0
    v4 = pool.view([0], [16], nb_pad=4, batch_pad=2)
    assert int(v4.lengths[1]) == 0
    assert int(v4.slots[1]) == pool.trash_slot
    assert int(v4.tables[1, 0]) == pool.trash_block


def test_paged_view_is_pytree(setup):
    """PagedCacheView must flow through jit/tree ops unchanged."""
    cfg, _ = setup
    pool = PagedKVCache(cfg, num_blocks=8, block_size=8, max_batch=2)
    pool.manager.allocate(0, 8)
    view = pool.view([0], [8], nb_pad=2, batch_pad=1)
    leaves, treedef = jax.tree.flatten(view)
    view2 = jax.tree.unflatten(treedef, leaves)
    assert view2.block_size == view.block_size
    assert jnp.array_equal(view2.tables, view.tables)
    assert len(jax.tree.leaves(view2.pool)) == len(jax.tree.leaves(pool.pool))
