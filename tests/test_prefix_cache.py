"""Prefix cache subsystem: radix index semantics, engine integration
(shared-prefix reuse must be invisible to greedy decode), LRU eviction
under memory pressure, the prefix-affinity router policy, the shared-
prefix workload generator, and the BCA effective-footprint hooks."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kvcache.paged import BlockManager
from repro.kvcache.prefix import PrefixIndex, prefix_cache_supported
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           ReplicatedCluster, shared_prefix_workload)
from repro.serving.cluster.router import PrefixAffinity, make_policy


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, rules, **kw):
    ecfg = EngineConfig(**{**dict(max_batch=4, block_size=16,
                                  kv_pool_tokens=8192, max_model_len=256,
                                  prefill_bucket=32, prefix_cache=True),
                           **kw})
    return ContinuousBatchingEngine(Model(cfg, rules), params, ecfg)


# ------------------------------------------------------------ the index --
def test_index_match_insert_full_blocks_only():
    bm = BlockManager(32, 4)
    idx = PrefixIndex(bm)
    toks = np.arange(11)                     # 2 full blocks + 3-token tail
    blocks = bm.allocate(0, 11)              # 3 blocks
    assert idx.insert(toks, blocks) == 2     # tail block never indexed
    assert idx.cached_blocks == 2
    # identical prompt: matched, capped at prompt_len - 1 -> 2 blocks
    assert idx.match(toks) == blocks[:2]
    # prompt == one full cached block exactly: cap leaves 1 block -> 0
    assert idx.match(toks[:4]) == []
    assert idx.match(toks[:9]) == blocks[:2]
    # diverging second block: only the first matches
    other = np.concatenate([toks[:4], [99, 99, 99, 99, 1]])
    assert idx.match(other) == blocks[:1]
    # re-insert of the same prompt adds nothing, keeps first writer
    blocks2 = bm.allocate(1, 11)
    assert idx.insert(toks, blocks2) == 0
    assert idx.match(toks) == blocks[:2]


def test_index_eviction_lru_and_pinning():
    bm = BlockManager(32, 4)
    idx = PrefixIndex(bm)
    a = bm.allocate(0, 8)                    # 2 blocks
    idx.insert(np.arange(8), a)
    b = bm.allocate(1, 8)
    idx.insert(np.arange(100, 108), b)
    bm.release(0)
    bm.release(1)
    idx.match(np.arange(9))                  # touch both A nodes: B is LRU
    assert idx.evict(1) == 1
    assert idx.match(np.arange(100, 109)) == b[:1]   # B's leaf went first
    assert idx.match(np.arange(9)) == a              # A intact
    # pinned blocks (a request still holds them) are not evictable
    bm.share(2, a)
    assert idx.evict(10) == 1                # b's remaining node only
    assert idx.cached_blocks == 2            # a0, a1 survive (pinned)
    bm.release(2)
    assert idx.evict(10) == 2
    assert idx.cached_blocks == 0
    assert bm.free_blocks == 32


def test_index_max_blocks_cap():
    bm = BlockManager(32, 4)
    idx = PrefixIndex(bm, max_blocks=2)
    blocks = bm.allocate(0, 16)
    # wants 4 nodes; the cap stops growth at 2 (nothing evictable: the
    # request still pins its blocks, so evict-on-insert frees none)
    idx.insert(np.arange(16), blocks)
    bm.release(0)
    assert idx.cached_blocks == 2


def test_index_cap_insert_never_evicts_attachment_point():
    """Regression: extending a cached chain at the cap used to evict the
    very leaf being extended, attaching the new node to a detached parent
    and leaking its pinned block forever."""
    bm = BlockManager(32, 4)
    idx = PrefixIndex(bm, max_blocks=2)
    a = np.arange(8)
    blocks = bm.allocate(0, 8)               # 2 blocks -> nodes a0, a1
    idx.insert(a, blocks)
    bm.release(0)                            # both nodes cache-only now
    longer = np.concatenate([a, np.arange(50, 54)])
    tail = bm.allocate(1, 4)                 # the extension's own block
    n_before = idx.cached_blocks
    idx.insert(longer, list(blocks) + tail)  # cap must block the growth
    bm.release(1)
    # the existing chain stays attached (a1 was NOT evicted from under
    # the insert) and everything remains reachable and reclaimable
    assert idx.match(np.concatenate([a, [0]])) == list(blocks)
    assert idx.cached_blocks == n_before
    idx.clear()
    assert idx.cached_blocks == 0
    assert bm.refs == {}
    assert bm.free_blocks == 32              # nothing leaked


def test_supported_gating():
    assert prefix_cache_supported(reduced(get_config("opt-1.3b")))[0]
    for arch in ("mamba2-1.3b", "zamba2-7b"):       # SSM state
        ok, why = prefix_cache_supported(reduced(get_config(arch)))
        assert not ok and why


# ------------------------------------------------------ engine semantics --
def test_engine_outputs_identical_with_cache(setup, rules):
    """The acceptance property: greedy outputs must be bit-identical with
    the prefix cache on and off, while prefill work and fresh block
    allocations drop by >= 2x on a shared-prefix workload."""
    cfg, params = setup
    outs, stats = {}, {}
    for on in (False, True):
        eng = _engine(cfg, params, rules, prefix_cache=on)
        reqs = shared_prefix_workload(2, 4, cfg.vocab_size, prefix_len=48,
                                      suffix_len=16, max_new_tokens=6,
                                      seed=0)
        m = eng.run(reqs)
        assert all(r.t_done is not None for r in reqs)
        outs[on] = [r.output_tokens for r in reqs]
        stats[on] = (eng.prefill_tokens_computed,
                     eng.pool.manager.total_allocations, m)
    assert outs[True] == outs[False]
    assert stats[False][0] >= 2 * stats[True][0]
    assert stats[False][1] >= 1.5 * stats[True][1]
    m_on = stats[True][2]
    assert m_on.prefix is not None and m_on.prefix.hit_tokens > 0
    assert 0.0 < m_on.prefix.hit_rate < 1.0
    assert m_on.kv_used_series and m_on.kv_used_mean > 0.0
    assert stats[False][2].prefix is None


def test_engine_downgrades_unsupported_config(rules):
    cfg = reduced(get_config("mamba2-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = _engine(cfg, params, rules)
    assert eng.prefix is None
    assert eng.prefix_disabled_reason


def test_engine_evicts_under_pressure(setup, rules):
    """Tiny pool + many distinct prompts: the cache must give blocks back
    (eviction) so admission keeps making progress, and every request must
    still finish."""
    cfg, params = setup
    eng = _engine(cfg, params, rules, kv_pool_tokens=256, max_batch=3,
                  max_model_len=128)
    reqs = shared_prefix_workload(4, 2, cfg.vocab_size, prefix_len=32,
                                  suffix_len=16, max_new_tokens=4, seed=1)
    m = eng.run(reqs)
    assert all(r.t_done is not None for r in reqs)
    assert eng.prefix.stats.blocks_evicted > 0
    assert m.max_kv_fraction <= 1.0


def test_cluster_prefix_affinity_and_aggregation(setup, rules):
    """2-replica sync cluster with prefix caches + affinity routing: each
    tenant stays home (after its first request), outputs match the
    cache-off cluster, and ClusterMetrics aggregates the reuse."""
    cfg, params = setup
    outs = {}
    for on in (False, True):
        ecfg = EngineConfig(max_batch=4, block_size=16, kv_pool_tokens=8192,
                            max_model_len=256, prefill_bucket=32,
                            prefix_cache=on)
        cluster = ReplicatedCluster.colocated(
            Model(cfg, rules), params, ecfg, 2,
            policy=PrefixAffinity(affinity_tokens=48), mode="sync")
        reqs = shared_prefix_workload(2, 4, cfg.vocab_size, prefix_len=48,
                                      suffix_len=16, max_new_tokens=5,
                                      seed=3)
        cm = cluster.run(reqs)
        assert cm.completed == len(reqs)
        outs[on] = [r.output_tokens for r in reqs]
        if on:
            assert cm.prefill_tokens_skipped > 0
            assert 0.0 < cm.prefix_hit_rate < 1.0
            assert cm.prefix_blocks_shared > 0
            assert cm.peak_kv_fraction > 0.0
            assert "prefix cache" in cm.summary()
            # affinity: with 2 tenants on 2 replicas, each tenant's 4
            # requests landed on one replica
            by_rep = [sorted(r.req_id % 2 for r in rep.requests)
                      for rep in cluster.replicas]
            assert all(len(set(ids)) <= 1 for ids in by_rep if ids)
    assert outs[True] == outs[False]


# ---------------------------------------------------------------- router --
class _Rep:
    def __init__(self, load):
        self.load = load


def _req(prompt):
    from repro.serving.workload import Request
    return Request(req_id=0, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=1)


def test_prefix_affinity_sticky_and_skew():
    pol = make_policy("prefix-affinity")
    assert isinstance(pol, PrefixAffinity)
    reps = [_Rep(0), _Rep(0)]
    a, b = np.arange(64), np.arange(100, 164)
    assert pol.choose(_req(a), reps) == 0          # new key -> least loaded
    reps[0].load = 1
    assert pol.choose(_req(b), reps) == 1          # different key
    reps[1].load = 2
    assert pol.choose(_req(a), reps) == 0          # sticky beats load...
    reps[0].load = 100
    assert pol.choose(_req(a), reps) == 1          # ...until skew bound
    reps[0].load = 0
    assert pol.choose(_req(a), reps) == 1          # re-homed, still sticky
    pol.reset()
    assert pol.choose(_req(a), reps) == 0          # forgotten


def test_prefix_affinity_key_is_prefix_only():
    pol = PrefixAffinity(affinity_tokens=8)
    reps = [_Rep(0), _Rep(0)]
    base = np.arange(32)
    idx = pol.choose(_req(base), reps)
    reps[1 - idx].load = 0
    reps[idx].load = 1
    # same first 8 tokens, different tail: same home
    variant = np.concatenate([base[:8], np.arange(500, 524)])
    assert pol.choose(_req(variant), reps) == idx


# -------------------------------------------------------------- workload --
def test_shared_prefix_workload_shape():
    reqs = shared_prefix_workload(3, 4, 1000, prefix_len=20, suffix_len=5,
                                  max_new_tokens=7, seed=0)
    assert len(reqs) == 12
    assert all(r.prompt_len == 25 for r in reqs)
    assert all(r.max_new_tokens == 7 for r in reqs)
    # interleaved: first 3 requests cover all 3 tenants
    heads = [r.prompt[:20].tobytes() for r in reqs]
    assert len(set(heads[:3])) == 3
    assert len(set(heads)) == 3                  # 3 distinct prefixes
    # every tenant's prefix identical across its requests
    for t in range(3):
        assert len({heads[i] for i in range(t, 12, 3)}) == 1
    # suffixes unique
    assert len({r.prompt[20:].tobytes() for r in reqs}) == 12
    back = shared_prefix_workload(3, 4, 1000, prefix_len=20, suffix_len=5,
                                  seed=0, interleave=False)
    bheads = [r.prompt[:20].tobytes() for r in back]
    assert len(set(bheads[:4])) == 1             # tenant-at-a-time
    with pytest.raises(ValueError, match="tenant"):
        shared_prefix_workload(0, 4, 100)
    with pytest.raises(ValueError, match="prefix_len"):
        shared_prefix_workload(1, 1, 100, prefix_len=0)


def test_shared_prefix_workload_arrivals():
    reqs = shared_prefix_workload(2, 4, 100, prefix_len=8, suffix_len=4,
                                  seed=0, arrival_rate=10.0)
    ts = [r.arrival_s for r in reqs]
    assert all(t > 0 for t in ts) and ts == sorted(ts)


# ------------------------------------------------------------- BCA hooks --
def test_bca_effective_kv_footprint():
    from repro.core import (H100_PAPER, BatchingConfigurationAdvisor,
                            decode_curves, max_batch_for, with_prefix_reuse)
    cfg = get_config("opt-1.3b")
    base = decode_curves(cfg, H100_PAPER, ctx=331, max_batch=64)
    scaled = with_prefix_reuse(base, 0.5)
    np.testing.assert_allclose(scaled.kv_fraction, base.kv_fraction * 0.5)
    np.testing.assert_allclose(scaled.throughput, base.throughput)
    curves2 = decode_curves(cfg, H100_PAPER, ctx=331, max_batch=64,
                            prefix_hit_rate=0.5)
    np.testing.assert_allclose(curves2.kv_fraction, scaled.kv_fraction)
    # the same HBM admits ~2x the requests at a 50% hit rate
    mb0 = max_batch_for(cfg, H100_PAPER, ctx=331)
    mb5 = max_batch_for(cfg, H100_PAPER, ctx=331, prefix_hit_rate=0.5)
    assert mb5 >= int(1.9 * mb0)
    slo = float(base.itl_s.max()) * 2
    r0 = BatchingConfigurationAdvisor(base, slo_s=slo).solve()
    r5 = BatchingConfigurationAdvisor(base, slo_s=slo,
                                      prefix_hit_rate=0.5).solve()
    assert r5.kv_fraction == pytest.approx(r0.kv_fraction * 0.5)
    with pytest.raises(ValueError, match="hit_rate"):
        with_prefix_reuse(base, 1.0)
