"""core.replication: ReplicationPlanner memory accounting and slice_mesh
shape/divisibility behaviour (multi-device shapes via a subprocess with
virtual host devices — the in-process device count is fixed at import)."""
import os
import subprocess
import sys

import pytest

from repro.configs import get_config
from repro.core.hardware import Hardware, H100_PAPER
from repro.core.replication import ReplicationPlanner, slice_mesh


@pytest.fixture(scope="module")
def cfg():
    return get_config("opt-1.3b")


def test_plan_memory_accounting(cfg):
    ctx, b = 331, 32
    planner = ReplicationPlanner(H100_PAPER, cfg, ctx=ctx)
    plan = planner.plan(b)
    model_b = cfg.num_params() * 2
    kv_b = cfg.kv_bytes_per_token(2) * ctx * b
    cap = H100_PAPER.hbm_bytes * 0.9
    assert plan.model_bytes == pytest.approx(model_b)
    assert plan.kv_bytes_per_replica == pytest.approx(kv_b)
    assert plan.capacity_bytes == pytest.approx(cap)
    assert plan.n_replicas == int(cap // (model_b + kv_b)) >= 1
    assert plan.total_bytes == pytest.approx(
        plan.n_replicas * (model_b + kv_b))
    assert plan.total_bytes <= plan.capacity_bytes
    assert plan.per_replica_batch == b
    assert "replicas" in plan.summary()


def test_plan_respects_max_replicas(cfg):
    plan = ReplicationPlanner(H100_PAPER, cfg, ctx=331).plan(
        8, max_replicas=2)
    assert plan.n_replicas == 2


def test_plan_never_below_one_replica(cfg):
    """Even when the model alone exceeds capacity the planner reports the
    degenerate 1-replica deployment rather than zero."""
    tiny = Hardware(name="tiny", peak_flops=1e12, hbm_bw=1e11,
                    link_bw=1e10, hbm_bytes=1e6)
    plan = ReplicationPlanner(tiny, cfg, ctx=331).plan(8)
    assert plan.n_replicas == 1
    assert plan.total_bytes > plan.capacity_bytes


def test_plan_reserve_fraction_shrinks_capacity(cfg):
    loose = ReplicationPlanner(H100_PAPER, cfg, ctx=331,
                               reserve_fraction=0.0).plan(32)
    tight = ReplicationPlanner(H100_PAPER, cfg, ctx=331,
                               reserve_fraction=0.5).plan(32)
    assert tight.capacity_bytes == pytest.approx(
        loose.capacity_bytes * 0.5)
    assert tight.n_replicas <= loose.n_replicas


def test_slice_mesh_identity_and_divisibility(mesh):
    subs = slice_mesh(mesh, 1)
    assert len(subs) == 1
    assert subs[0].axis_names == mesh.axis_names
    assert subs[0].shape == mesh.shape
    with pytest.raises(ValueError, match="not divisible"):
        slice_mesh(mesh, 2)       # data axis has size 1


_SLICE_SCRIPT = """
import numpy as np
from repro.compat import make_mesh
from repro.core.replication import slice_mesh

mesh = make_mesh((4, 1), ("data", "model"))
for r, per in ((2, 2), (4, 1)):
    subs = slice_mesh(mesh, r)
    assert len(subs) == r
    seen = set()
    for sub in subs:
        assert sub.axis_names == mesh.axis_names
        assert sub.shape["data"] == per and sub.shape["model"] == 1
        ids = {d.id for d in np.asarray(sub.devices).flat}
        assert not ids & seen          # disjoint slices
        seen |= ids
    assert seen == {d.id for d in np.asarray(mesh.devices).flat}
try:
    slice_mesh(mesh, 3)
except ValueError:
    pass
else:
    raise AssertionError("slice_mesh(4-dev, 3) should not divide")
print("OK")
"""


def test_slice_mesh_multi_device_shapes():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", _SLICE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
