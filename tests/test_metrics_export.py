"""Metrics export: JSON round-trip fidelity (Percentiles, PrefixStats,
per-replica stats, the robustness counters), Prometheus text exposition +
lint, registry coverage, and loud failures on unknown schemas."""
import dataclasses
import json

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           FaultInjector, ReplicatedCluster, StepFunctions,
                           lint_prometheus, metrics_from_json,
                           metrics_to_json, prometheus_text,
                           shared_prefix_workload, sharegpt_like)
from repro.serving.cluster.metrics import ClusterMetrics
from repro.serving.metrics import Percentiles, ServingMetrics
from repro.serving.obs.export import (CLUSTER_SPECS, SERVING_SPECS,
                                      _resolve)


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def serving_metrics(setup):
    """A real run with the prefix cache on, so PrefixStats is attached."""
    cfg, params, model, steps = setup
    eng = ContinuousBatchingEngine(model, params,
                                   _ecfg(prefix_cache=True), steps=steps)
    reqs = shared_prefix_workload(2, 2, cfg.vocab_size, prefix_len=32,
                                  suffix_len=8, max_new_tokens=6, seed=5)
    return eng.run(reqs)


@pytest.fixture(scope="module")
def cluster_metrics(setup):
    """A real faulted cluster run: the PR 6 robustness counters are live
    (faults/redriven/availability), not defaulted."""
    cfg, params, model, _ = setup
    faults = FaultInjector.parse("replica=1,step=3")
    cluster = ReplicatedCluster.colocated(model, params, _ecfg(), 2,
                                          policy="round-robin", mode="sync",
                                          faults=faults)
    reqs = sharegpt_like(6, cfg.vocab_size, seed=3, mean_in=12,
                         mean_out=8, max_len=48, sigma=0.4)
    return cluster.run(reqs)


# ------------------------------------------------------- JSON round-trip --
def test_serving_metrics_roundtrip(serving_metrics, tmp_path):
    m = serving_metrics
    assert m.prefix is not None and m.prefix.hit_tokens > 0
    doc = metrics_to_json(m)
    got = metrics_from_json(doc)                       # dict form
    assert isinstance(got, ServingMetrics)
    assert dataclasses.asdict(got) == dataclasses.asdict(m)
    assert isinstance(got.itl, Percentiles) and got.itl == m.itl
    assert got.prefix.hit_rate == m.prefix.hit_rate

    got2 = metrics_from_json(json.dumps(doc))          # string form
    assert dataclasses.asdict(got2) == dataclasses.asdict(m)

    path = tmp_path / "m.json"
    path.write_text(json.dumps(doc))
    got3 = metrics_from_json(str(path))                # file form
    assert dataclasses.asdict(got3) == dataclasses.asdict(m)


def test_cluster_metrics_roundtrip_with_robustness(cluster_metrics):
    m = cluster_metrics
    assert m.faults == 1 and m.redriven > 0            # counters are live
    got = metrics_from_json(metrics_to_json(m))
    assert isinstance(got, ClusterMetrics)
    assert dataclasses.asdict(got) == dataclasses.asdict(m)
    assert got.faults == m.faults and got.redriven == m.redriven
    assert got.availability == m.availability
    assert got.watchdog_trips == m.watchdog_trips
    # per-replica ServingMetrics come back as real dataclasses
    assert all(isinstance(rs.metrics, ServingMetrics)
               for rs in got.per_replica)
    assert all(isinstance(rs.metrics.ttft, Percentiles)
               for rs in got.per_replica)


def test_metrics_from_json_fails_loudly():
    with pytest.raises(ValueError, match="schema"):
        metrics_from_json({"schema": "bogus/v9", "type": "ServingMetrics",
                           "data": {}})
    with pytest.raises(ValueError, match="type"):
        metrics_from_json({"schema": "repro.serving.metrics/v1",
                           "type": "Mystery", "data": {}})
    with pytest.raises(TypeError):
        metrics_to_json({"not": "a metrics object"})


# ----------------------------------------------------------- Prometheus --
def test_prometheus_serving_exposition(serving_metrics):
    text = prometheus_text(serving_metrics)
    assert lint_prometheus(text) == []
    assert "# TYPE repro_tokens_total counter" in text
    assert 'repro_itl_seconds{quantile="0.95"}' in text
    assert "repro_prefix_hit_rate" in text             # prefix cache was on


def test_prometheus_cluster_exposition(cluster_metrics):
    text = prometheus_text(cluster_metrics)
    assert lint_prometheus(text) == []
    assert "repro_cluster_faults_total 1" in text
    assert "repro_cluster_redriven_total" in text
    # replica-labeled serving samples survive the aggregation
    assert 'replica="0"' in text and 'replica="1"' in text
    with pytest.raises(TypeError):
        prometheus_text({"not": "metrics"})


def test_lint_catches_malformed_exposition():
    assert lint_prometheus("va lue{ 1.0\n")            # bad sample line
    assert lint_prometheus("# TYPE x flavor\nx 1\n")   # bad TYPE
    assert lint_prometheus('m{a=unquoted} 1\n')        # bad label
    assert lint_prometheus("m nope\n")                 # non-numeric value
    assert lint_prometheus("") == []


# -------------------------------------------------------------- registry --
def test_registry_covers_all_spec_paths(serving_metrics, cluster_metrics):
    """Every registry path resolves on a real metrics object — a renamed
    dataclass field breaks here, not silently in the exposition."""
    for spec in SERVING_SPECS:
        _resolve(serving_metrics, spec.path)           # must not raise
    for spec in CLUSTER_SPECS:
        _resolve(cluster_metrics, spec.path)


def test_registry_covers_robustness_counters():
    cluster_paths = {s.path for s in CLUSTER_SPECS}
    for field in ("faults", "redriven", "lost", "shed", "deadline_expired",
                  "watchdog_trips", "availability"):
        assert field in cluster_paths, f"{field} missing from registry"
    serving_paths = {s.path for s in SERVING_SPECS}
    for field in ("preemptions", "shed", "deadline_expired",
                  "queued_aborts", "shed_reasons"):
        assert field in serving_paths, f"{field} missing from registry"


def test_registry_covers_spec_counters():
    serving_paths = {s.path for s in SERVING_SPECS}
    for field in ("spec_steps", "spec_drafted", "spec_accepted",
                  "spec_rejected", "spec_acceptance_rate"):
        assert field in serving_paths, f"{field} missing from registry"
    cluster_paths = {s.path for s in CLUSTER_SPECS}
    for field in ("spec_steps", "spec_drafted", "spec_accepted",
                  "spec_rejected"):
        assert field in cluster_paths, f"{field} missing from registry"


def test_registry_names_unique():
    names = [s.name for s in SERVING_SPECS + CLUSTER_SPECS]
    assert len(names) == len(set(names))
    kinds = {s.kind for s in SERVING_SPECS + CLUSTER_SPECS}
    assert kinds <= {"counter", "gauge", "summary", "labeled"}
