"""Request deadlines + admission-control backpressure.

Deadline expiry must work in every phase — queued (pre-admission),
mid-PREFILLING (chunked), mid-decode, and via the streaming facade
across replicas — releasing KV blocks and prefix-cache pins the same
step. Load shedding must be a graceful finish ("shed"), never an engine
exception, with a per-reason breakdown; preemptions and queued aborts
are first-class metrics series."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           ReplicatedCluster, Request, SamplingParams,
                           ServingAPI, StepFunctions, sharegpt_like)
from repro.serving.workload import (FINISH_ABORT, FINISH_DEADLINE,
                                    FINISH_LENGTH, FINISH_SHED, FINISH_STOP)


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


def _engine(setup, **kw):
    _, params, model, steps = setup
    return ContinuousBatchingEngine(model, params, _ecfg(**kw), steps=steps)


def _req(cfg, rid, n=12, seed=0, **sp):
    rng = np.random.default_rng(seed + rid)
    return Request(req_id=rid,
                   prompt=rng.integers(0, cfg.vocab_size, n,
                                       dtype=np.int32),
                   arrival_s=0.0,
                   sampling=SamplingParams(**sp))


SERVED = (FINISH_LENGTH, FINISH_STOP)


# --------------------------------------------------------- SamplingParams --
def test_deadline_params_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        SamplingParams(deadline_s=0)
    with pytest.raises(ValueError, match="ttft_deadline_s"):
        SamplingParams(ttft_deadline_s=-1)
    sp = SamplingParams(deadline_s=2.0, ttft_deadline_s=0.5)
    assert sp.has_deadline
    assert not SamplingParams().has_deadline
    # strict >: at exactly the deadline the request is still live
    assert not sp.expired(0.0, 2.0, first_token=True)
    assert sp.expired(0.0, 2.01, first_token=True)
    # ttft deadline only binds before the first token
    assert sp.expired(0.0, 0.6, first_token=False)
    assert not sp.expired(0.0, 0.6, first_token=True)


# ------------------------------------------------------- expiry by phase --
def test_deadline_expires_pre_admission(setup):
    eng = _engine(setup)
    req = _req(setup[0], 0, deadline_s=0.5, max_new_tokens=8)
    free0 = eng.pool.manager.free_blocks
    eng.add_request(req)
    eng.step(1.0)                          # past the deadline while queued
    assert req.finish_reason == FINISH_DEADLINE
    assert req.generated == 0 and req.t_done == 1.0
    assert eng.deadline_expired == 1
    assert eng.pool.manager.free_blocks == free0   # nothing ever allocated
    assert not eng.busy


def test_deadline_expires_mid_prefill_chunked(setup):
    eng = _engine(setup, prefill_chunk_tokens=16)
    req = _req(setup[0], 0, n=48, ttft_deadline_s=0.5, max_new_tokens=8)
    free0 = eng.pool.manager.free_blocks
    eng.add_request(req)
    eng.step(0.0)                          # first chunk only (48 > 16)
    assert req in eng.prefilling
    assert eng.pool.manager.free_blocks < free0    # partial prompt KV held
    eng.step(1.0)                          # expires mid-PREFILLING
    assert req.finish_reason == FINISH_DEADLINE
    assert req.t_first_token is None and req.generated == 0
    # the partial prompt's blocks came back the same step
    assert eng.pool.manager.free_blocks == free0
    assert not eng._prefilled and not eng.prefilling


def test_deadline_expires_mid_decode_keeps_partial_output(setup):
    eng = _engine(setup)
    req = _req(setup[0], 0, deadline_s=1.0, max_new_tokens=32)
    free0 = eng.pool.manager.free_blocks
    eng.add_request(req)
    eng.step(0.0)                          # prefill + first token
    assert req in eng.running and req.generated >= 1
    for _ in range(3):
        eng.step(0.5)                      # still inside the deadline
    partial = list(req.output_tokens)
    assert len(partial) >= 4
    eng.step(2.0)                          # expires mid-decode
    assert req.finish_reason == FINISH_DEADLINE
    assert list(req.output_tokens) == partial      # partial output kept
    assert 0 < req.generated < 32
    assert eng.pool.manager.free_blocks == free0   # blocks released now
    assert eng.deadline_expired == 1


def test_ttft_deadline_stops_binding_after_first_token(setup):
    eng = _engine(setup)
    req = _req(setup[0], 0, ttft_deadline_s=0.5, max_new_tokens=6)
    eng.add_request(req)
    eng.step(0.0)                          # first token inside the SLO
    assert req.t_first_token is not None
    while eng.busy:
        eng.step(2.0)                      # way past ttft — irrelevant now
    assert req.finish_reason in SERVED
    assert req.generated == 6


def test_deadline_releases_prefix_pins_same_step(setup):
    """An expiring request sharing cached prefix blocks drops its pins
    the step it expires: private blocks return to the free list, shared
    ones fall back to cache-only refcount and stay reusable."""
    cfg = setup[0]
    eng = _engine(setup, prefix_cache=True)
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, 32, dtype=np.int32)

    warm = Request(req_id=0, prompt=prefix.copy(), arrival_s=0.0,
                   sampling=SamplingParams(max_new_tokens=2))
    eng.add_request(warm)
    while eng.busy:
        eng.step(0.0)
    assert warm.finish_reason in SERVED
    cached0 = eng.prefix.cached_blocks
    assert cached0 > 0
    free0 = eng.pool.manager.free_blocks

    tail = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    doomed = Request(req_id=1,
                     prompt=np.concatenate([prefix, tail]),
                     arrival_s=0.0,
                     sampling=SamplingParams(max_new_tokens=32,
                                             deadline_s=1.0))
    eng.add_request(doomed)
    eng.step(0.0)                          # admit with a prefix hit
    assert eng.pool.manager.free_blocks < free0
    eng.step(2.0)                          # expire mid-decode
    assert doomed.finish_reason == FINISH_DEADLINE
    # same-step reclaim: no block is pinned by the dead request — every
    # block is either free or cache-owned (its prompt blocks may have
    # been adopted by the cache on release, which is reuse, not a leak)
    assert eng.pool.manager.free_blocks + eng.prefix.cached_blocks \
        == free0 + cached0
    assert eng.prefix.cached_blocks >= cached0

    fresh = Request(req_id=2, prompt=prefix.copy(), arrival_s=0.0,
                    sampling=SamplingParams(max_new_tokens=2))
    eng.add_request(fresh)
    while eng.busy:
        eng.step(0.0)
    assert fresh.finish_reason in SERVED   # cache still serves hits
    assert eng.prefix.stats.hit_tokens > 0


def test_deadline_streaming_cross_replica(setup):
    """ServingAPI.stream over a 2-replica cluster: the deadline finish
    arrives as a terminal GenerationOutput event, and the expiry count
    aggregates into ClusterMetrics."""
    cfg = setup[0]
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync")
    api = ServingAPI(cluster)
    normal = [api.submit(_req(cfg, i, seed=70, max_new_tokens=5))
              for i in range(2)]
    # raw-prompt submit: arrival_s = now, so the deadline clock starts
    # here; big output budget guarantees expiry beats completion
    rng = np.random.default_rng(73)
    doomed = api.submit(rng.integers(0, cfg.vocab_size, 12,
                                     dtype=np.int32),
                        SamplingParams(max_new_tokens=64,
                                       deadline_s=0.03))
    events = list(api.stream(doomed))
    assert events and events[-1].finished
    assert events[-1].finish_reason == FINISH_DEADLINE
    api.drain()
    for h in normal:
        assert h.finish_reason in SERVED
    m = api.metrics()
    assert m.deadline_expired == 1
    assert m.finish_reasons[FINISH_DEADLINE] == 1


# ------------------------------------------------------------- shedding --
def test_shed_queue_full_is_graceful(setup):
    api = ServingAPI(_engine(setup, max_waiting=1, max_batch=1))
    cfg = setup[0]
    h1 = api.submit(_req(cfg, 0, seed=80, max_new_tokens=4))
    h2 = api.submit(_req(cfg, 1, seed=80, max_new_tokens=4))
    assert not h1.done and h2.done         # queue bound hit, no exception
    assert h2.finish_reason == FINISH_SHED
    events = list(api.stream(h2))          # stream still terminates
    assert len(events) == 1 and events[0].finished \
        and events[0].finish_reason == FINISH_SHED
    api.drain()
    assert h1.finish_reason in SERVED
    m = api.metrics()
    assert m.shed == 1 and m.shed_reasons == {"queue_full": 1}
    assert m.finish_reasons[FINISH_SHED] == 1


def test_shed_kv_pressure(setup):
    eng = _engine(setup, shed_kv_fraction=0.05, max_batch=1,
                  kv_pool_tokens=256, max_model_len=64)
    api = ServingAPI(eng)
    cfg = setup[0]
    h1 = api.submit(_req(cfg, 0, n=24, seed=81, max_new_tokens=16))
    for _ in range(2):
        api._pump_once()                   # h1 decoding, pool in use
    assert eng.pool.manager.used_fraction >= 0.05
    h2 = api.submit(_req(cfg, 1, seed=81, max_new_tokens=4))
    assert not h2.done                     # queued: pressure needs a queue
    h3 = api.submit(_req(cfg, 2, seed=81, max_new_tokens=4))
    assert h3.done and h3.finish_reason == FINISH_SHED
    api.drain()
    assert h1.finish_reason in SERVED and h2.finish_reason in SERVED
    assert api.metrics().shed_reasons == {"kv_pressure": 1}


def test_shed_queue_delay_and_unmeetable_deadline(setup):
    cfg = setup[0]
    eng = _engine(setup)
    eng.run(sharegpt_like(3, cfg.vocab_size, seed=6, mean_in=10,
                          mean_out=8, max_len=32, sigma=0.2))
    assert eng.estimated_queue_delay_s() == 0.0    # empty queue
    # queue up committed work so the estimate is positive
    eng.add_request(_req(cfg, 10, n=24, seed=82, max_new_tokens=64))
    est = eng.estimated_queue_delay_s()
    assert est > 0.0
    # pure checks: the policy knob and the per-request deadline version
    hopeless = _req(cfg, 11, seed=82, max_new_tokens=4,
                    deadline_s=min(est / 2, 1e-4))
    assert eng.shed_check(hopeless, now=0.0) == "deadline_unmeetable"
    fine = _req(cfg, 12, seed=82, max_new_tokens=4, deadline_s=est + 60)
    assert eng.shed_check(fine, now=0.0) is None
    eng2 = _engine(setup, shed_queue_delay_s=1e-6)
    eng2.itl_samples.extend(eng.itl_samples)
    eng2.decode_token_samples.extend(eng.decode_token_samples)
    eng2.add_request(_req(cfg, 13, n=24, seed=82, max_new_tokens=64))
    assert eng2.shed_check(_req(cfg, 14, seed=82, max_new_tokens=4),
                           now=0.0) == "queue_delay"


def test_cluster_sheds_only_when_every_replica_full(setup):
    cfg = setup[0]
    engines = [_engine(setup, max_waiting=1, max_batch=1)
               for _ in range(2)]
    cluster = ReplicatedCluster(engines, mode="sync")
    reqs = [_req(cfg, i, seed=83, max_new_tokens=4) for i in range(6)]
    m = cluster.run(reqs)                  # overload: degrades, no raise
    assert m.shed > 0
    assert m.completed == 6                # every request reached t_done
    assert all(r.t_done is not None for r in reqs)
    served = [r for r in reqs if r.finish_reason in SERVED]
    shed = [r for r in reqs if r.finish_reason == FINISH_SHED]
    assert len(served) + len(shed) == 6 and served
    assert m.finish_reasons[FINISH_SHED] == len(shed) == m.shed
    assert "queue_full" in cluster.shed_reasons


# ----------------------------------------------- satellite metric series --
def test_queued_abort_counter_engine(setup):
    api = ServingAPI(_engine(setup, max_batch=1))
    cfg = setup[0]
    h1 = api.submit(_req(cfg, 0, seed=84, max_new_tokens=4))
    h2 = api.submit(_req(cfg, 1, seed=84, max_new_tokens=4))
    assert api.abort(h2)                   # still in the arrival queue
    assert h2.finish_reason == FINISH_ABORT
    api.drain()
    m = api.metrics()
    assert m.queued_aborts == 1
    assert h1.finish_reason in SERVED


def test_queued_abort_counter_cluster(setup):
    cfg = setup[0]
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                mode="sync")
    api = ServingAPI(cluster)
    handles = [api.submit(_req(cfg, i, seed=85, max_new_tokens=4))
               for i in range(3)]
    assert api.abort(handles[2])           # routed but never admitted
    api.drain()
    m = api.metrics()
    assert m.queued_aborts == 1
    assert m.finish_reasons[FINISH_ABORT] == 1


def test_preemptions_are_first_class_series(setup):
    cfg = setup[0]
    reqs = sharegpt_like(6, cfg.vocab_size, seed=11, mean_in=20,
                         mean_out=36, max_len=60, sigma=0.1)
    tight = _engine(setup, max_batch=3, kv_pool_tokens=128,
                    max_model_len=96)
    m = tight.run(reqs)
    assert tight.preemptions > 0
    assert m.preemptions == tight.preemptions
    assert sum(m.preemption_series) == m.preemptions
    assert "preempt=" in m.robustness_row()

    reqs2 = sharegpt_like(6, cfg.vocab_size, seed=11, mean_in=20,
                          mean_out=36, max_len=60, sigma=0.1)
    cluster = ReplicatedCluster(
        [_engine(setup, max_batch=3, kv_pool_tokens=128,
                 max_model_len=96)],
        mode="sync")
    cm = cluster.run(reqs2)
    assert cm.preemptions > 0
    assert cm.per_replica[0].metrics.preemptions \
        == cm.per_replica[0].preemptions == cm.preemptions
    assert sum(cm.per_replica[0].metrics.preemption_series) \
        == cm.preemptions
