"""Online serving facade: submit / stream / abort / drain semantics,
finish-reason accounting (stop tokens release blocks the same step), the
frozen-Request/RequestState split, and the engine.run() clock-restore
regression."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           ReplicatedCluster, Request, SamplingParams,
                           ServingAPI, sharegpt_like)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(setup, rules, **kw):
    cfg, params = setup
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=256, prefill_bucket=16)
    base.update(kw)
    return ContinuousBatchingEngine(Model(cfg, rules), params,
                                    EngineConfig(**base))


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


# ------------------------------------------------------- request model ----
def test_request_input_fields_are_frozen(setup):
    cfg, _ = setup
    req = Request(req_id=0, prompt=_prompt(cfg, 8), max_new_tokens=4)
    for field, value in (("req_id", 1), ("prompt", None),
                         ("arrival_s", 2.0),
                         ("sampling", SamplingParams())):
        with pytest.raises(AttributeError):
            setattr(req, field, value)
    # engine-owned state stays writable through the legacy proxies
    req.t_first_token = 1.0
    req.generated = 3
    req.output_tokens = [1, 2, 3]
    assert req.state.generated == 3 and req.state.output_tokens == [1, 2, 3]
    assert req.max_new_tokens == 4 == req.sampling.max_new_tokens


def test_request_budget_conflict_rejected(setup):
    cfg, _ = setup
    with pytest.raises(TypeError):
        Request(req_id=0, prompt=_prompt(cfg, 8))        # no budget at all
    with pytest.raises(ValueError):
        Request(req_id=0, prompt=_prompt(cfg, 8), max_new_tokens=4,
                sampling=SamplingParams(max_new_tokens=5))
    # agreeing is fine
    req = Request(req_id=0, prompt=_prompt(cfg, 8), max_new_tokens=5,
                  sampling=SamplingParams(max_new_tokens=5))
    assert req.max_new_tokens == 5


# ------------------------------------------------------------ streaming ----
def test_stream_yields_deltas_and_final_reason(setup, rules):
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    h = api.submit(_prompt(cfg, 10), SamplingParams(max_new_tokens=6))
    events = list(api.stream(h))
    assert events, "stream produced no events"
    assert all(not e.finished for e in events[:-1])
    assert events[-1].finished and events[-1].finish_reason == "length"
    # deltas concatenate to the cumulative ids, which match the request
    cat = [t for e in events for t in e.new_token_ids]
    assert tuple(cat) == events[-1].token_ids
    assert list(events[-1].token_ids) == h.request.output_tokens
    assert len(cat) == 6


def test_stream_equals_batch_run(setup, rules):
    """The facade is a wrapper, not a fork: same tokens as run()."""
    cfg, _ = setup
    sp = SamplingParams(temperature=0.7, top_p=0.9, seed=13)
    wl = lambda: sharegpt_like(4, cfg.vocab_size, seed=5,    # noqa: E731
                               mean_in=12, mean_out=6, max_len=48,
                               sigma=0.3, sampling=sp)
    reqs = wl()
    _engine(setup, rules).run(reqs)
    api = ServingAPI(_engine(setup, rules))
    handles = [api.submit(r) for r in wl()]
    outs = api.drain()
    assert ([list(outs[h.req_id].token_ids) for h in handles]
            == [list(map(int, r.output_tokens)) for r in reqs])
    m = api.metrics()
    assert m.n_completed == 4
    assert m.finish_reasons == {"length": 4}


def test_generate_convenience(setup, rules):
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    out = api.generate(_prompt(cfg, 9), SamplingParams(max_new_tokens=3))
    assert out.finished and out.finish_reason == "length"
    assert len(out.token_ids) == 3


# ---------------------------------------------------------------- abort ----
def test_abort_mid_decode_reclaims_blocks(setup, rules):
    cfg, _ = setup
    eng = _engine(setup, rules)
    api = ServingAPI(eng)
    free0 = eng.pool.manager.free_blocks
    h = api.submit(_prompt(cfg, 24), SamplingParams(max_new_tokens=100))
    for _ in range(3):
        api._backend.pump(api._clock())
    assert h.request.generated > 1 and not h.done
    assert api.abort(h)
    assert eng.pool.manager.free_blocks == free0
    assert not eng.busy
    ev = list(api.stream(h))
    assert len(ev) >= 1 and ev[-1].finished
    assert ev[-1].finish_reason == "abort"
    assert api.metrics().finish_reasons == {"abort": 1}
    # double-abort and unknown ids are clean no-ops
    assert not api.abort(h)
    assert not api.abort(12345)


def test_abort_mid_prefill_reclaims_blocks(setup, rules):
    """Abort in the PREFILLING phase (chunked): the half-streamed prompt's
    blocks must all return to the pool."""
    cfg, _ = setup
    eng = _engine(setup, rules, prefill_chunk_tokens=16)
    assert eng.chunking
    api = ServingAPI(eng)
    free0 = eng.pool.manager.free_blocks
    h = api.submit(_prompt(cfg, 100), SamplingParams(max_new_tokens=4))
    api._backend.pump(api._clock())          # one 16-token chunk
    assert eng._prefilled.get(h.req_id, 0) > 0, "not mid-PREFILLING"
    assert api.abort(h)
    assert eng.pool.manager.free_blocks == free0
    assert not eng.busy and h.done and h.finish_reason == "abort"
    assert h.request.generated == 0


def test_abort_with_prefix_cache_restores_refcounts(setup, rules):
    """Aborting a request that spliced shared prefix blocks must drop
    exactly its references: cached blocks stay warm at refcount 1."""
    cfg, _ = setup
    eng = _engine(setup, rules, prefix_cache=True)
    api = ServingAPI(eng)
    base = _prompt(cfg, 32, seed=3)
    api.generate(base, SamplingParams(max_new_tokens=2))   # warm the cache
    cached = {n.block for n in eng.prefix._iter_nodes()}
    assert cached, "warmup should have inserted prefix blocks"
    assert all(eng.pool.manager.ref_count(b) == 1 for b in cached)
    # same prefix, longer tail -> splices the cached blocks
    h = api.submit(np.concatenate([base, _prompt(cfg, 16, seed=4)]),
                   SamplingParams(max_new_tokens=50))
    for _ in range(2):
        api._backend.pump(api._clock())
    assert eng.prefix.stats.hits >= 1
    assert api.abort(h)
    assert all(eng.pool.manager.ref_count(b) == 1 for b in cached), \
        "abort must return shared blocks to their cache-only refcount"


def test_abort_future_arrival_never_negative_e2e(setup, rules):
    """Aborting a queued request whose (simulated) arrival hasn't come
    yet must clamp t_done to arrival_s — no negative E2E in collect()."""
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    h = api.submit(_prompt(cfg, 8), SamplingParams(max_new_tokens=4),
                   arrival_s=1e6)
    assert api.abort(h)
    assert h.request.t_done >= h.request.arrival_s
    m = api.metrics()
    assert m.e2e.p50 >= 0.0


def test_simulated_future_arrivals_keep_timeline_monotonic(setup, rules):
    """Fast-forwarding to a simulated arrival must floor every later
    timestamp: t_done can never land behind the jump (the facade analogue
    of run()'s 'keep now monotonic' guard)."""
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    near = api.submit(_prompt(cfg, 8), SamplingParams(max_new_tokens=2))
    far = api.submit(_prompt(cfg, 8, seed=1),
                     SamplingParams(max_new_tokens=3), arrival_s=50.0)
    outs = api.drain()
    assert outs[near.req_id].finished and outs[far.req_id].finished
    for h in (near, far):
        r = h.request
        assert r.arrival_s <= r.t_first_token <= r.t_done
    m = api.metrics()
    assert m.e2e.p50 >= 0.0
    # wall is anchored at first submit; the simulated 50 s jump dominates
    assert m.wall_s >= 45.0


def test_release_prunes_finished_handles(setup, rules):
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    h = api.submit(_prompt(cfg, 8), SamplingParams(max_new_tokens=2))
    assert not api.release(h), "in-flight handles must not be releasable"
    api.drain()
    assert api.release(h)
    assert not api.release(h)
    assert api.metrics().n_completed == 0
    assert h.req_id not in api.drain()


def test_submit_prebuilt_request_rejects_overrides(setup, rules):
    """arrival_s/sampling are frozen on a prebuilt Request — a silently
    ignored override would defer/sample nothing with no indication."""
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    req = Request(req_id=0, prompt=_prompt(cfg, 8), max_new_tokens=2)
    with pytest.raises(ValueError):
        api.submit(req, arrival_s=5.0)
    with pytest.raises(ValueError):
        api.submit(req, SamplingParams())


def test_abort_queued_request(setup, rules):
    """Abort before admission: nothing was allocated, nothing leaks."""
    cfg, _ = setup
    eng = _engine(setup, rules, max_batch=1)
    api = ServingAPI(eng)
    h1 = api.submit(_prompt(cfg, 12), SamplingParams(max_new_tokens=30))
    api._backend.pump(api._clock())          # h1 occupies the only seat
    h2 = api.submit(_prompt(cfg, 12, seed=9),
                    SamplingParams(max_new_tokens=5))
    assert len(eng.waiting) == 1
    assert api.abort(h2)
    assert not eng.waiting and h2.finish_reason == "abort"
    api.abort(h1)
    assert not eng.busy


# ------------------------------------------------------- stop tokens ----
def test_stop_token_finishes_same_step_and_releases_blocks(setup, rules):
    """A stop-token finish must release KV the same step and account the
    stop token exactly like a length finish (symmetric ITL/decode work);
    the breakdown only differs in finish_reasons."""
    cfg, _ = setup
    wl = lambda sp: sharegpt_like(1, cfg.vocab_size, seed=8,  # noqa: E731
                                  mean_in=12, mean_out=20, max_len=48,
                                  sigma=0.1, sampling=sp)
    reqs = wl(None)
    _engine(setup, rules).run(reqs)
    full = list(map(int, reqs[0].output_tokens))
    assert len(full) >= 4
    stop_tok = full[3]
    cut = full.index(stop_tok)               # first occurrence wins
    sp = SamplingParams(stop_token_ids=(stop_tok,))
    eng = _engine(setup, rules)
    reqs2 = wl(sp)
    m = eng.run(reqs2)
    got = list(map(int, reqs2[0].output_tokens))
    assert got == full[:cut + 1], "stop token itself is emitted, then ends"
    assert reqs2[0].finish_reason == "stop"
    assert m.finish_reasons == {"stop": 1}
    assert m.output_tokens == cut + 1
    assert eng.pool.manager.free_blocks == eng.pool.manager.num_blocks
    # ignore_eos decodes straight through the stop token
    reqs3 = wl(dataclasses.replace(sp, ignore_eos=True))
    _engine(setup, rules).run(reqs3)
    assert list(map(int, reqs3[0].output_tokens)) == full
    assert reqs3[0].finish_reason == "length"


def test_stop_token_on_first_prefill_token(setup, rules):
    """First sampled token is a stop token: finish straight out of
    prefill, one token emitted, reason 'stop'."""
    cfg, _ = setup
    probe = sharegpt_like(1, cfg.vocab_size, seed=8, mean_in=12,
                          mean_out=20, max_len=48, sigma=0.1)
    _engine(setup, rules).run(probe)
    first = int(probe[0].output_tokens[0])
    sp = SamplingParams(stop_token_ids=(first,))
    reqs = sharegpt_like(1, cfg.vocab_size, seed=8, mean_in=12,
                         mean_out=20, max_len=48, sigma=0.1, sampling=sp)
    eng = _engine(setup, rules)
    eng.run(reqs)
    assert list(map(int, reqs[0].output_tokens)) == [first]
    assert reqs[0].finish_reason == "stop"
    assert not eng.busy


# ----------------------------------------------------- clock regression ----
def test_run_restores_clock_for_back_to_back_runs(setup, rules):
    """engine.run() must not leave its epoch installed: a second run — or
    facade/step use after one — stamps on its own timeline."""
    cfg, _ = setup
    eng = _engine(setup, rules)
    assert eng.clock is None
    wl = lambda s: sharegpt_like(3, cfg.vocab_size, seed=s,  # noqa: E731
                                 mean_in=10, mean_out=5, max_len=48,
                                 sigma=0.3)
    m1 = eng.run(wl(2))
    assert eng.clock is None, "run() left its wall clock installed"
    m2 = eng.run(wl(3))
    assert eng.clock is None
    # second run's timestamps live on its own timeline, not offset by the
    # first run's epoch: E2E must be bounded by the second run's wall
    assert m2.n_completed == 3
    assert m2.e2e.p99 <= m2.wall_s + 1e-6
    assert m1.e2e.p99 <= m1.wall_s + 1e-6
    # interleaved facade use after a run stamps small facade-clock times
    api = ServingAPI(eng)
    out = api.generate(_prompt(cfg, 8), SamplingParams(max_new_tokens=2))
    req = api._submitted[0]
    assert out.finished
    assert req.t_done is not None
    assert req.t_done <= api._clock() + 1e-6


def test_cluster_run_restores_clocks(setup, rules):
    cfg, params = setup
    model = Model(cfg, rules)
    ecfg = EngineConfig(max_batch=4, block_size=8, kv_pool_tokens=4096,
                        max_model_len=128, prefill_bucket=16)
    cluster = ReplicatedCluster.colocated(model, params, ecfg, 2,
                                          mode="sync")
    reqs = sharegpt_like(4, cfg.vocab_size, seed=2, mean_in=10,
                         mean_out=5, max_len=48, sigma=0.3)
    m = cluster.run(reqs)
    assert m.completed == 4
    assert all(rep.engine.clock is None for rep in cluster.replicas)


# -------------------------------------------------------- cluster facade ----
def test_facade_over_cluster_routes_and_streams(setup, rules):
    """Router-aware submit + cross-replica streaming + abort through the
    same facade surface."""
    cfg, params = setup
    model = Model(cfg, rules)
    ecfg = EngineConfig(max_batch=2, block_size=8, kv_pool_tokens=4096,
                        max_model_len=128, prefill_bucket=16)
    cluster = ReplicatedCluster.colocated(model, params, ecfg, 2,
                                          policy="round-robin", mode="sync")
    api = ServingAPI(cluster)
    h = [api.submit(_prompt(cfg, 10, seed=i),
                    SamplingParams(max_new_tokens=4 if i < 2 else 100))
         for i in range(3)]
    assert cluster.router.assigned == [2, 1]
    events = list(api.stream(h[1]))          # lives on replica 1
    assert events[-1].finished and len(events[-1].token_ids) == 4
    assert api.abort(h[2])                   # replica 0, mid-flight
    outs = api.drain()
    assert outs[h[0].req_id].finish_reason == "length"
    assert outs[h[2].req_id].finish_reason == "abort"
    m = api.metrics()
    assert m.completed == 3
    assert m.finish_reasons == {"length": 2, "abort": 1}
    for rep in cluster.replicas:
        mgr = rep.engine.pool.manager
        assert mgr.free_blocks == mgr.num_blocks
    # release prunes the replica's routed list too (no phantom rows)
    assert api.release(h[0])
    assert sum(len(rep.requests) for rep in cluster.replicas) == 2
    assert api.metrics().completed == 2


def test_cluster_facade_defers_routing_to_arrival(setup, rules):
    """Future-arrival submits must not be routed against a t=0 snapshot:
    the policy runs when the arrival comes, seeing live load — run()
    parity for queue-aware routers."""
    cfg, params = setup
    model = Model(cfg, rules)
    ecfg = EngineConfig(max_batch=2, block_size=8, kv_pool_tokens=4096,
                        max_model_len=128, prefill_bucket=16)
    cluster = ReplicatedCluster.colocated(model, params, ecfg, 2,
                                          policy="jsq", mode="sync")
    api = ServingAPI(cluster)
    reqs = [Request(req_id=i, prompt=_prompt(cfg, 10, seed=i),
                    arrival_s=5.0 + i, sampling=SamplingParams(
                        max_new_tokens=3)) for i in range(3)]
    handles = [api.submit(r) for r in reqs]
    assert cluster.router.assigned == [0, 0], \
        "future arrivals must not be routed at submit time"
    assert api._backend.pending == reqs
    # abort one while still pending: never routed, nothing allocated
    assert api.abort(handles[2])
    assert handles[2].done and handles[2].finish_reason == "abort"
    assert handles[2].request.t_done >= reqs[2].arrival_s
    outs = api.drain()
    assert sum(cluster.router.assigned) == 2
    assert not api._backend.pending
    for h in handles[:2]:
        assert outs[h.req_id].finish_reason == "length"
        assert h.request.arrival_s <= h.request.t_first_token \
            <= h.request.t_done
    # the never-routed abort still shows up in session metrics, exactly
    # like an engine-backend abort of a queued request would
    m = api.metrics()
    assert m.completed == 3
    assert m.finish_reasons == {"length": 2, "abort": 1}
    # ...and releasing it prunes it from the breakdown again
    assert api.release(handles[2])
    assert api.metrics().completed == 2


def test_metrics_wall_anchored_at_first_submit(setup, rules):
    """Idle time before the first submit must not deflate throughput."""
    cfg, _ = setup
    api = ServingAPI(_engine(setup, rules))
    api._t0 -= 100.0                 # simulate a 100 s idle session head
    out = api.generate(_prompt(cfg, 8), SamplingParams(max_new_tokens=3))
    assert out.finished
    m = api.metrics()
    assert m.wall_s < 100.0, "pre-submit idle counted into wall_s"
    assert m.output_tokens == 3
