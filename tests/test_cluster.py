"""Replicated serving cluster: a 1-replica sync cluster must be
token-for-token identical to the bare engine, routing policies must
balance load deterministically, per-replica state (pools, preemption
accounting) must not leak between co-located engines, and the autoscale
decision (curves -> BCA -> plan -> launch size) must be exact on
synthetic curves."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.hardware import H100_PAPER
from repro.core.perfmodel import ServingCurves
from repro.models.model import Model, init_params
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           ReplicatedCluster, StepFunctions, sharegpt_like)
from repro.serving.cluster import decide, make_policy
from repro.serving.cluster.router import (JoinShortestQueue, LeastKVLoad,
                                          RoundRobin, Router)


@pytest.fixture(scope="module")
def setup(rules):
    cfg = reduced(get_config("opt-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg, rules)
    # one shared compile cache for every engine in this module (all use
    # block_size=8), so replicas don't recompile identical programs
    steps = StepFunctions.build(model, 8)
    return cfg, params, model, steps


def _ecfg(**kw):
    base = dict(max_batch=4, block_size=8, kv_pool_tokens=4096,
                max_model_len=128, prefill_bucket=16)
    base.update(kw)
    return EngineConfig(**base)


def _engine(setup, **kw):
    _, params, model, steps = setup
    return ContinuousBatchingEngine(model, params, _ecfg(**kw), steps=steps)


def _wl(cfg, n=4, seed=2, mean_out=6):
    return sharegpt_like(n, cfg.vocab_size, seed=seed, mean_in=12,
                         mean_out=mean_out, max_len=48, sigma=0.4)


# ------------------------------------------------------------ scheduler --
def test_sync_single_replica_matches_bare_engine(setup):
    """Deterministic mode: the cluster wrapper must be invisible — same
    tokens as running the engine directly."""
    cfg = setup[0]
    bare = _wl(cfg)
    _engine(setup).run(bare)
    cluster = ReplicatedCluster([_engine(setup)], mode="sync")
    routed = _wl(cfg)
    m = cluster.run(routed)
    assert m.completed == len(routed)
    assert ([r.output_tokens for r in routed]
            == [r.output_tokens for r in bare])


def test_threaded_mode_matches_sync_mode(setup):
    """Thread-per-replica stepping must not change any output token."""
    cfg = setup[0]
    outs = {}
    for mode in ("sync", "thread"):
        cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                    mode=mode)
        reqs = _wl(cfg, n=4, seed=3)
        m = cluster.run(reqs)
        assert m.completed == 4
        outs[mode] = [r.output_tokens for r in reqs]
    assert outs["sync"] == outs["thread"]


def test_round_robin_two_replicas_aggregates(setup):
    cfg = setup[0]
    cluster = ReplicatedCluster([_engine(setup), _engine(setup)],
                                policy="round-robin", mode="sync")
    reqs = _wl(cfg, n=6, seed=4, mean_out=5)
    m = cluster.run(reqs)
    assert cluster.router.assigned == [3, 3]
    assert m.completed == 6 and m.n_replicas == 2
    assert [r.n_requests for r in m.per_replica] == [3, 3]
    assert m.total_tokens == sum(r.metrics.total_tokens
                                 for r in m.per_replica)
    assert m.output_tokens == sum(r.generated for r in reqs)
    assert m.goodput_rps > 0 and m.output_throughput > 0
    assert 0 < m.ttft.p50 <= m.ttft.p95 <= m.ttft.p99
    assert 0 < m.itl.p50 <= m.itl.p99
    for r in m.per_replica:
        assert 0 < r.occupancy <= 1.0
        assert r.completed == 3
    assert m.summary()         # renders


def test_preemption_isolated_per_replica(setup):
    """Two engines sharing a host: replica 0's pool exhaustion/preemption
    churn must not perturb replica 1's tokens or accounting (no
    module-level serving state)."""
    cfg = setup[0]
    reqs = sharegpt_like(6, cfg.vocab_size, seed=11, mean_in=20,
                         mean_out=36, max_len=60, sigma=0.1)
    # replica 0: pool too small for its 3 requests to finish un-preempted;
    # replica 1: roomy pool, must stay preemption-free
    e0 = _engine(setup, max_batch=3, kv_pool_tokens=128, max_model_len=96)
    e1 = _engine(setup, max_batch=3, kv_pool_tokens=2048, max_model_len=96)
    cluster = ReplicatedCluster([e0, e1], policy="round-robin", mode="sync")
    m = cluster.run(reqs)
    assert m.completed == 6
    assert e0.preemptions > 0, "replica 0 was meant to hit pool exhaustion"
    assert e1.preemptions == 0
    assert m.per_replica[0].preemptions == e0.preemptions
    # pool accounting drained cleanly on BOTH engines
    for eng in (e0, e1):
        assert eng.pool.manager.tables == {}
        assert len(eng.pool.manager.free) == eng.pool.manager.num_blocks
        assert len(eng.pool._free_slots) == eng.ecfg.max_batch
    # replica 1's tokens match an undisturbed bare-engine run of its share
    alone = sharegpt_like(6, cfg.vocab_size, seed=11, mean_in=20,
                          mean_out=36, max_len=60, sigma=0.1)
    odd = [r for i, r in enumerate(alone) if i % 2 == 1]
    _engine(setup, max_batch=3, kv_pool_tokens=2048,
            max_model_len=96).run(odd)
    assert ([r.output_tokens for i, r in enumerate(reqs) if i % 2 == 1]
            == [r.output_tokens for r in odd])


def test_timed_arrivals_keep_nonnegative_ttft(setup):
    """Fast-forwarded idle time (run() jumping `now` to the next arrival)
    must not produce TTFT/E2E stamped behind the arrival time."""
    cfg = setup[0]
    reqs = sharegpt_like(3, cfg.vocab_size, seed=6, mean_in=10, mean_out=4,
                         max_len=32, sigma=0.2, arrival_rate=0.5)
    assert reqs[-1].arrival_s > 1.0      # well ahead of the wall clock
    m = _engine(setup).run(reqs)
    assert m.n_completed == 3
    for r in reqs:
        assert r.t_first_token >= r.arrival_s
        assert r.t_done >= r.t_first_token
    # fast-forwarded admissions may legitimately stamp TTFT == 0 (idle
    # engine jumps straight to the arrival); negative is the bug
    assert m.ttft_s >= 0 and m.ttft.p50 >= 0 and m.e2e.p50 >= 0


def test_shared_steps_must_match_engine_config(setup):
    _, params, model, steps = setup
    with pytest.raises(ValueError, match="block_size"):
        ContinuousBatchingEngine(model, params, _ecfg(block_size=16),
                                 steps=steps)
    other = Model(model.cfg, model.rules)
    with pytest.raises(ValueError, match="different Model"):
        ContinuousBatchingEngine(other, params, _ecfg(), steps=steps)


def test_cluster_constructor_validation(setup):
    with pytest.raises(ValueError, match="at least one"):
        ReplicatedCluster([])
    with pytest.raises(ValueError, match="mode"):
        ReplicatedCluster([_engine(setup)], mode="warp")
    with pytest.raises(ValueError, match="meshes"):
        ReplicatedCluster([_engine(setup)], meshes=[None, None])


# --------------------------------------------------------------- router --
@dataclasses.dataclass
class _Stub:
    queue_depth: int = 0
    in_flight: int = 0
    kv_load: float = 0.0

    @property
    def load(self):
        return self.queue_depth + self.in_flight


def test_round_robin_cycles():
    p = RoundRobin()
    reps = [_Stub(), _Stub(), _Stub()]
    assert [p.choose(None, reps) for _ in range(5)] == [0, 1, 2, 0, 1]
    p.reset()
    assert p.choose(None, reps) == 0


def test_jsq_prefers_short_queue_breaking_ties_low():
    p = JoinShortestQueue()
    assert p.choose(None, [_Stub(3, 1), _Stub(1, 1), _Stub(0, 2)]) == 1
    assert p.choose(None, [_Stub(1, 1), _Stub(2, 0), _Stub(0, 2)]) == 0


def test_least_kv_prefers_free_pool_then_queue():
    p = LeastKVLoad()
    assert p.choose(None, [_Stub(0, 0, 0.9), _Stub(5, 0, 0.1)]) == 1
    assert p.choose(None, [_Stub(2, 0, 0.5), _Stub(1, 0, 0.5)]) == 1


def test_policy_registry():
    assert make_policy("jsq").name == "jsq"
    inst = LeastKVLoad()
    assert make_policy(inst) is inst
    with pytest.raises(ValueError, match="unknown router policy"):
        make_policy("random-telepathy")
    r = Router("round-robin", 2)
    r.route(None, [_Stub(), _Stub()])
    assert r.assigned == [1, 0]


# ------------------------------------------------------------ autoscale --
def test_autoscale_decide_on_synthetic_curves():
    curves = ServingCurves(
        batches=np.array([1., 2, 4, 8, 16, 32]),
        throughput=np.array([10., 19, 35, 60, 70, 71]),
        itl_s=np.array([.010, .011, .012, .013, .020, .040]),
        kv_fraction=np.array([.02, .04, .08, .16, .32, .64]))
    cfg = get_config("opt-1.3b")
    # slo = 2 x ITL(B=1) = 20ms -> B=32 infeasible -> B_opt = 16
    d = decide(curves, hw=H100_PAPER, cfg=cfg, ctx=331, slo_factor=2.0,
               eps=0.1, mesh_slices=6)
    assert d.bca.b_opt == 16 and d.per_replica_batch == 16
    assert d.slo_s == pytest.approx(0.020)
    assert d.plan.n_replicas >= 6       # H100 fits many tiny-KV replicas
    assert d.n_replicas == 6            # capped to the mesh slice count
    # memory-feasible count below the slice count: largest divisor wins
    d2 = decide(curves, hw=H100_PAPER, cfg=cfg, ctx=331, slo_factor=2.0,
                eps=0.1, max_replicas=4, mesh_slices=6)
    assert d2.plan.n_replicas == 4 and d2.n_replicas == 3
    assert "launch" in d2.summary()
